"""DIN — Deep Interest Network (Zhou et al., arXiv:1706.06978).

Target attention over the user behavior sequence:

  a_t  = MLP([h_t, e_tgt, h_t - e_tgt, h_t ⊙ e_tgt])   (attn MLP 80-40-1)
  u    = Σ_t a_t · h_t                                   (masked by hist len)
  ŷ    = MLP([u, e_tgt, dense])                          (DNN 200-80-1)

Embedding tables (items + categories) are row-sharded over ``model``
(`repro.models.recsys.embedding`).  Entry points:

  * ``loss_fn``          — BCE training step input (``train_batch``)
  * ``score``            — pointwise CTR scoring (``serve_p99`` / ``serve_bulk``)
  * ``score_candidates`` — one user against ``n_candidates`` items, fully
    batched (``retrieval_cand``): the candidate axis becomes the batch axis
    of the same attention + MLP stack; no loops.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.shardings import constraint
from repro.models.common import ParamSpec, dot
from repro.models.recsys.embedding import lookup, table_spec


@dataclasses.dataclass(frozen=True)
class DINConfig:
    embed_dim: int = 18
    seq_len: int = 100
    attn_mlp: Tuple[int, ...] = (80, 40)
    mlp: Tuple[int, ...] = (200, 80)
    n_items: int = 10_000_000
    n_cats: int = 10_000
    d_dense: int = 8  # user/context dense features
    interaction: str = "target-attn"

    @property
    def d_emb(self) -> int:
        return 2 * self.embed_dim  # item ⊕ category


def param_specs(cfg: DINConfig) -> Dict[str, ParamSpec]:
    de = cfg.d_emb
    specs: Dict[str, ParamSpec] = {
        "item_table": table_spec(cfg.n_items, cfg.embed_dim),
        "cat_table": table_spec(cfg.n_cats, cfg.embed_dim),
    }
    dims_a = [4 * de] + list(cfg.attn_mlp) + [1]
    for i in range(len(dims_a) - 1):
        specs[f"attn_w{i}"] = ParamSpec((dims_a[i], dims_a[i + 1]), (None, None), jnp.float32)
        specs[f"attn_b{i}"] = ParamSpec((dims_a[i + 1],), (None,), jnp.float32, init="zeros")
    dims_m = [2 * de + cfg.d_dense] + list(cfg.mlp) + [1]
    for i in range(len(dims_m) - 1):
        specs[f"mlp_w{i}"] = ParamSpec((dims_m[i], dims_m[i + 1]), (None, None), jnp.float32)
        specs[f"mlp_b{i}"] = ParamSpec((dims_m[i + 1],), (None,), jnp.float32, init="zeros")
    return specs


def _dice(x):  # PReLU-ish smooth activation used by DIN; sigmoid-gated here
    return x * jax.nn.sigmoid(x)


def _mlp(params, prefix: str, n: int, x: jnp.ndarray) -> jnp.ndarray:
    for i in range(n):
        x = dot(x, params[f"{prefix}_w{i}"]) + params[f"{prefix}_b{i}"]
        if i < n - 1:
            x = _dice(x)
    return x


def _embed_pairs(params, cfg: DINConfig, item_ids: jnp.ndarray, cat_ids: jnp.ndarray) -> jnp.ndarray:
    return jnp.concatenate(
        [lookup(params["item_table"], item_ids), lookup(params["cat_table"], cat_ids)],
        axis=-1,
    )


def interest(
    params, cfg: DINConfig,
    hist: jnp.ndarray,  # [B, L, 2*de?] embedded history
    target: jnp.ndarray,  # [B, de*2]
    hist_len: jnp.ndarray,  # [B]
) -> jnp.ndarray:
    """Target attention pooling over the behavior sequence."""
    b, l, de = hist.shape
    tgt = jnp.broadcast_to(target[:, None, :], (b, l, de))
    ain = jnp.concatenate([hist, tgt, hist - tgt, hist * tgt], axis=-1)
    n_attn = len(cfg.attn_mlp) + 1
    logits = _mlp(params, "attn", n_attn, ain.reshape(b * l, -1)).reshape(b, l)
    mask = jnp.arange(l)[None, :] < hist_len[:, None]
    # DIN uses un-normalized sigmoid-free weights with masking (paper §4.3);
    # we keep softmax-free weighting but zero the padding.
    w = jnp.where(mask, logits, 0.0)
    return jnp.einsum("bl,bld->bd", w, hist)


def score(params, cfg: DINConfig, batch: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    """CTR logits for (user history, target item) pairs.  Batch keys:
    hist_items/hist_cats [B, L], hist_len [B], target_item/target_cat [B],
    dense [B, d_dense]."""
    hist = _embed_pairs(params, cfg, batch["hist_items"], batch["hist_cats"])
    hist = constraint(hist, ("batch", None, None))
    tgt = _embed_pairs(params, cfg, batch["target_item"], batch["target_cat"])
    u = interest(params, cfg, hist, tgt, batch["hist_len"])
    x = jnp.concatenate([u, tgt, batch["dense"]], axis=-1)
    n_mlp = len(cfg.mlp) + 1
    return _mlp(params, "mlp", n_mlp, x)[:, 0]


def loss_fn(params, cfg: DINConfig, batch):
    logits = score(params, cfg, batch)
    y = batch["click"].astype(jnp.float32)
    loss = jnp.mean(
        jnp.maximum(logits, 0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )
    return loss, {"loss": loss}


def score_candidates(params, cfg: DINConfig, batch: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    """Retrieval scoring: one user, ``n_candidates`` target items.

    Batch keys: hist_items/hist_cats [1, L], hist_len [1], cand_items [Nc],
    cand_cats [Nc], dense [1, d_dense].  Returns scores [Nc].
    """
    nc = batch["cand_items"].shape[0]
    wide = {
        "hist_items": jnp.broadcast_to(batch["hist_items"], (nc, cfg.seq_len)),
        "hist_cats": jnp.broadcast_to(batch["hist_cats"], (nc, cfg.seq_len)),
        "hist_len": jnp.broadcast_to(batch["hist_len"], (nc,)),
        "target_item": batch["cand_items"],
        "target_cat": batch["cand_cats"],
        "dense": jnp.broadcast_to(batch["dense"], (nc, cfg.d_dense)),
    }
    return score(params, cfg, wide)
