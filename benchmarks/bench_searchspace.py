"""C4/C5/C6 — search-space size and states/sec for RI-DS vs RI-DS-SI vs
RI-DS-SI-FC (paper Figs. 7, 8, 9, 12).

States-explored is deterministic, so this benchmark reproduces the paper's
search-space claims exactly (up to the synthetic collections).  Expected,
per the paper:
  * SI reduces search space on all collections (C4);
  * FC further reduces it on GRAEMLIN32-like inputs, neutral elsewhere (C5);
  * time gains lag search-space gains (states/sec drops slightly — C6).
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from benchmarks import common
from repro.core import EngineConfig

VARIANTS = ("ri-ds", "ri-ds-si", "ri-ds-si-fc")


def run(scale: float = 0.5, seed: int = 7) -> Dict:
    cfg = EngineConfig(n_workers=1, expand_width=8)
    collections = common.bench_instances(scale=scale, seed=seed)
    rows: List[Dict] = []
    out: Dict[str, Dict] = {}
    for cname, instances in collections.items():
        per_variant = {v: {"states": [], "wall": [], "matches": []} for v in VARIANTS}
        cache: dict = {}
        for inst in instances:
            for v in VARIANTS:
                r = common.run_instance(inst, variant=v, cfg=cfg, packed_cache=cache)
                per_variant[v]["states"].append(r.states)
                per_variant[v]["wall"].append(r.wall_s)
                per_variant[v]["matches"].append(r.matches)
        base_m = per_variant["ri-ds"]["matches"]
        for v in VARIANTS:
            assert per_variant[v]["matches"] == base_m, (
                f"{cname}: {v} changed match counts — pruning must be sound"
            )
        summary = {}
        for v in VARIANTS:
            st = np.array(per_variant[v]["states"], dtype=np.float64)
            wl = np.array(per_variant[v]["wall"], dtype=np.float64)
            summary[v] = {
                "mean_states": float(st.mean()),
                "std_states": float(st.std()),
                "total_states": float(st.sum()),
                "total_wall_s": float(wl.sum()),
                "states_per_sec": float(st.sum() / max(wl.sum(), 1e-9)),
            }
        out[cname] = summary
        base = summary["ri-ds"]["total_states"]
        for v in VARIANTS:
            red = summary[v]["total_states"] / max(base, 1)
            rows.append(dict(collection=cname, variant=v,
                             states=summary[v]["total_states"],
                             reduction_vs_rids=red,
                             states_per_sec=summary[v]["states_per_sec"]))
    out["_rows"] = rows
    common.save_json("searchspace", out)
    return out


def emit_csv(out: Dict) -> List[str]:
    lines = []
    for row in out["_rows"]:
        us = 1e6 / max(row["states_per_sec"], 1e-9)
        lines.append(common.csv_row(
            f"searchspace/{row['collection']}/{row['variant']}",
            us,
            f"states={row['states']:.0f};reduction={row['reduction_vs_rids']:.3f}",
        ))
    return lines


if __name__ == "__main__":
    print("\n".join(emit_csv(run())))
