"""graphsage-reddit — 2L d_hidden=128 aggregator=mean sample_sizes=25-10.
[arXiv:1706.02216; paper]

The ``minibatch_lg`` cell consumes blocks from the real neighbor sampler
(`repro.models.gnn.sampler.NeighborSampler`, fanout 15-10 per the shape
spec); skewed block sizes are spread across shards with the paper-derived
LPT balancer (``balance_buckets``) before the jitted step.
"""

from repro.configs.gnn_common import GnnModelDef, GnnShape, make_gnn_arch
from repro.models.gnn import sage

CFG = sage.SAGEConfig(n_layers=2, d_hidden=128, aggregator="mean", sample_sizes=(25, 10))


def fwd_flops(cfg: sage.SAGEConfig, shape: GnnShape) -> float:
    dims = [shape.d_feat] + [cfg.d_hidden] * (cfg.n_layers - 1) + [shape.d_out]
    f = 0.0
    for i in range(cfg.n_layers):
        f += 2.0 * 2.0 * shape.n_nodes * dims[i] * dims[i + 1]  # self + nbr
        f += 2.0 * shape.n_edges * dims[i]  # mean aggregation adds
    return f


ARCH = make_gnn_arch(
    GnnModelDef(
        name="graphsage-reddit",
        cfg=CFG,
        param_specs=sage.param_specs,
        forward=lambda params, cfg, batch: sage.forward(params, cfg, batch),
        fwd_flops=fwd_flops,
        notes="minibatch_lg uses the paper's load-balancing insight for "
        "skewed sampled blocks (DESIGN.md §4).",
    )
)
