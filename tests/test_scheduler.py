"""Work-stealing scheduler invariants (plan_steals / balance_assignment)."""

import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import scheduler
from repro.core.scheduler import StealPolicy, plan_steals, receiver_workers


@settings(max_examples=50, deadline=None)
@given(
    sizes=st.lists(st.integers(0, 40), min_size=2, max_size=32),
    chunk=st.integers(1, 8),
    keep=st.integers(0, 4),
    cap=st.integers(1, 8),
)
def test_plan_steals_invariants(sizes, chunk, keep, cap):
    policy = StealPolicy(steal_chunk=chunk, keep_min=keep, recv_cap=cap)
    s = jnp.asarray(sizes, jnp.int32)
    donate, accepted, dest_rank, dest_pos = (
        np.asarray(x) for x in plan_steals(s, policy)
    )
    sizes_np = np.asarray(sizes)
    hungry = sizes_np == 0
    n_recv = hungry.sum()

    # donors never drop below keep_min; only > keep_min donate
    assert np.all(donate <= np.maximum(sizes_np - keep, 0))
    assert np.all(donate[sizes_np <= keep] == 0)
    assert np.all(donate <= chunk)
    # accepted is a prefix of the offer
    assert np.all(accepted <= donate)
    # work conservation: every accepted slot has a destination rank
    n_assigned = (dest_rank >= 0).sum()
    assert n_assigned == accepted.sum()
    if n_recv == 0:
        assert accepted.sum() == 0
        return
    # receivers capped
    ranks, counts = np.unique(dest_rank[dest_rank >= 0], return_counts=True)
    assert np.all(counts <= cap)
    assert np.all(ranks < n_recv)
    # intake positions unique per rank
    for r in ranks:
        pos = dest_pos[dest_rank == r]
        assert len(set(pos.tolist())) == len(pos)


def test_receiver_workers():
    s = jnp.asarray([3, 0, 5, 0, 0], jnp.int32)
    wor = np.asarray(receiver_workers(s))
    assert wor[:3].tolist() == [1, 3, 4]
    assert np.all(wor[3:] == -1)


def test_balance_assignment_beats_roundrobin(rng):
    w = rng.pareto(1.5, size=64) + 0.1  # heavy-tailed like subgraph work
    n = 8
    lpt = scheduler.balance_assignment(w, n)
    rr = np.arange(64) % n
    assert scheduler.imbalance(w, lpt, n) <= scheduler.imbalance(w, rr, n) + 1e-9
    # LPT guarantee: makespan <= 4/3 * OPT; OPT >= max(mean load, max item)
    mean_load = w.sum() / n
    opt_lb = max(mean_load, w.max())
    makespan = np.bincount(lpt, weights=w, minlength=n).max()
    assert makespan <= 4.0 / 3.0 * opt_lb + 1e-9


def test_balance_assignment_covers_all_shards(rng):
    w = np.ones(16)
    out = scheduler.balance_assignment(w, 4)
    assert sorted(np.unique(out).tolist()) == [0, 1, 2, 3]
    assert np.all(np.bincount(out, minlength=4) == 4)
