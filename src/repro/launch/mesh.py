"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — device count is locked
at first jax initialization, and only launch/dryrun.py forces the
512-placeholder-device environment.
"""

from __future__ import annotations

import math
from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """The target topology: one v5e pod = (data=16, model=16) = 256 chips;
    multi-pod adds a leading pod axis: (pod=2, data=16, model=16) = 512."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for the production mesh, have {len(devices)}; "
            "run via launch/dryrun.py (it forces "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512)"
        )
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_local_mesh(axes: Sequence[str] = ("data", "model")) -> Mesh:
    """Trivial mesh over however many devices exist (smoke tests: 1)."""
    n = len(jax.devices())
    shape = (n,) + (1,) * (len(axes) - 1)
    return jax.make_mesh(shape, tuple(axes), devices=jax.devices())


def describe(mesh: Mesh) -> str:
    return f"mesh{dict(mesh.shape)} over {mesh.devices.size} devices"
