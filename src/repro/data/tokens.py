"""LM token data pipeline: sharded on-disk token store with resumable,
deterministic batching.

Production shape: fixed-size ``.npy`` token shards + a JSON manifest; the
loader memory-maps shards, yields ``(tokens, labels)`` batches in a
seed-deterministic shuffled order, and exposes/accepts a cursor so a
restarted job resumes mid-epoch exactly where the checkpoint left it
(fault-tolerance tie-in: `repro.train.trainer.TrainLoop` stores the cursor
in ``extra_meta``).
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

_MANIFEST = "tokens_manifest.json"


def write_shards(tokens: np.ndarray, out_dir: str, shard_tokens: int = 1 << 20) -> int:
    """Split a flat int32 token stream into .npy shards + manifest."""
    os.makedirs(out_dir, exist_ok=True)
    tokens = np.asarray(tokens, np.int32).reshape(-1)
    n_shards = max(1, (len(tokens) + shard_tokens - 1) // shard_tokens)
    sizes = []
    for i in range(n_shards):
        chunk = tokens[i * shard_tokens:(i + 1) * shard_tokens]
        np.save(os.path.join(out_dir, f"shard_{i:05d}.npy"), chunk)
        sizes.append(int(len(chunk)))
    with open(os.path.join(out_dir, _MANIFEST), "w") as f:
        json.dump({"n_shards": n_shards, "sizes": sizes,
                   "total_tokens": int(len(tokens))}, f)
    return n_shards


@dataclasses.dataclass
class Cursor:
    epoch: int = 0
    step: int = 0

    def to_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d) -> "Cursor":
        return Cursor(int(d.get("epoch", 0)), int(d.get("step", 0)))


class TokenLoader:
    """Deterministic, resumable batch iterator over a token-shard dir."""

    def __init__(self, data_dir: str, batch: int, seq: int, seed: int = 0):
        with open(os.path.join(data_dir, _MANIFEST)) as f:
            self.manifest = json.load(f)
        self.data_dir = data_dir
        self.batch = batch
        self.seq = seq
        self.seed = seed
        self._mmaps = [
            np.load(os.path.join(data_dir, f"shard_{i:05d}.npy"), mmap_mode="r")
            for i in range(self.manifest["n_shards"])
        ]
        total = self.manifest["total_tokens"]
        self.samples_per_epoch = max(1, (total - 1) // (seq + 1))
        self.steps_per_epoch = max(1, self.samples_per_epoch // batch)

    def _sample(self, epoch: int, idx: int) -> np.ndarray:
        order = np.random.default_rng(self.seed + epoch).permutation(
            self.samples_per_epoch
        )
        start = int(order[idx % self.samples_per_epoch]) * (self.seq + 1)
        flat = self._flat_slice(start, self.seq + 1)
        return flat

    def _flat_slice(self, start: int, n: int) -> np.ndarray:
        out = np.empty(n, np.int32)
        pos = 0
        si = 0
        acc = 0
        sizes = self.manifest["sizes"]
        while si < len(sizes) and acc + sizes[si] <= start:
            acc += sizes[si]
            si += 1
        off = start - acc
        while pos < n and si < len(sizes):
            take = min(n - pos, sizes[si] - off)
            out[pos:pos + take] = self._mmaps[si][off:off + take]
            pos += take
            off = 0
            si += 1
        if pos < n:  # wrap (last sample of the stream)
            out[pos:] = out[:n - pos]
        return out

    def batches(self, cursor: Optional[Cursor] = None) -> Iterator[Tuple[Dict, Cursor]]:
        """Yields ``(batch_dict, cursor_after)`` pairs, forever."""
        cur = cursor or Cursor()
        while True:
            rows = [
                self._sample(cur.epoch, cur.step * self.batch + b)
                for b in range(self.batch)
            ]
            arr = np.stack(rows)
            yield (
                {"tokens": arr[:, :-1].copy(), "labels": arr[:, 1:].copy()},
                Cursor(cur.epoch, cur.step + 1),
            )
            cur = Cursor(cur.epoch, cur.step + 1)
            if cur.step >= self.steps_per_epoch:
                cur = Cursor(cur.epoch + 1, 0)
