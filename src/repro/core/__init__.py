"""Core subgraph-enumeration library (the paper's contribution).

Layers:
  graph      — host graph + packed-bitmap representations
  ordering   — RI GreatestConstraintFirst ordering (+ SI tie-break)
  domains    — RI-DS domains: init, arc consistency, forward checking
  plan       — SearchPlan: static arrays for the engine
  delta      — dynamic-graph delta algebra: GraphDelta edit sets,
               edge-anchored seeding, match invalidation / dedup,
               DeltaMatchSet (DESIGN.md §8)
  frontier   — ring-buffer worker stacks: SoA state + pop/push/compact ops
  extend     — the expansion step behind the StepBackend seam
               (jnp reference / fused Pallas extend_step kernel /
               sparse-CSR sorted-intersection walk, auto-selected by
               target size)
  engine     — while_loop drivers, steal rounds, shard_map glue
  scheduler  — steal-round policy (shared with the GNN batch balancer)
  ref        — sequential + brute-force oracles
  session    — prepared-query session API (SubgraphIndex / Query /
               Enumerator / MatchSet, shape-bucketed compile cache)
  api        — enumerate_subgraphs() one-shot compatibility wrapper
  multi      — deprecated batch wrapper (enumerate_many) over the session
"""

from repro.core.api import EnumerationResult, enumerate_subgraphs
from repro.core.delta import DeltaMatchSet, GraphDelta
from repro.core.engine import EngineConfig, EngineResult
from repro.core.graph import CsrPlaneSet, Graph, PackedGraph
from repro.core.plan import SearchPlan, VARIANTS, build_plan
from repro.core.session import (
    Enumerator,
    MatchSet,
    Query,
    SHAPE_BUCKETS,
    SubgraphIndex,
    prepare_query,
    snap_p_pad,
)

__all__ = [
    "CsrPlaneSet",
    "DeltaMatchSet",
    "EnumerationResult",
    "enumerate_subgraphs",
    "EngineConfig",
    "EngineResult",
    "Enumerator",
    "Graph",
    "GraphDelta",
    "MatchSet",
    "PackedGraph",
    "Query",
    "SHAPE_BUCKETS",
    "SearchPlan",
    "SubgraphIndex",
    "VARIANTS",
    "build_plan",
    "prepare_query",
    "snap_p_pad",
]
