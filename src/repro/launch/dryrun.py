import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# The two lines above MUST run before any other import (jax locks the device
# count at first initialization).  Everything below is ordinary code.

"""Multi-pod dry run: lower + compile every (architecture × input shape ×
mesh) cell with ShapeDtypeStruct inputs — no allocation — and record
memory/cost/collective analyses for §Dry-run and §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                  # all cells, both meshes
  PYTHONPATH=src python -m repro.launch.dryrun --arch din --shape serve_bulk
  PYTHONPATH=src python -m repro.launch.dryrun --mesh multi --force
  PYTHONPATH=src python -m repro.launch.dryrun --list

Results land in artifacts/dryrun/<mesh>/<arch>__<shape>.json (resumable —
existing results are skipped unless --force).
"""

import argparse
import json
import sys
import time
import traceback

import jax

from repro.configs import registry
from repro.distributed.shardings import tree_shardings
from repro.launch.mesh import describe, make_production_mesh

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", ".."))

DEFAULT_OUT = os.path.join(
    os.path.dirname(__file__), "..", "..", "..", "artifacts", "dryrun"
)


def run_cell(cell, mesh, save_hlo: bool = False, hlo_gz_path=None):
    """Lower + compile one cell on ``mesh``; return the result record."""
    from benchmarks import hlo_analysis  # repo-root import (benchmarks pkg)

    build = cell.build()
    in_sh = tuple(
        tree_shardings(log, ab, mesh) for log, ab in zip(build.logical, build.args)
    )
    t0 = time.perf_counter()
    with mesh:
        jitted = jax.jit(build.fn, in_shardings=in_sh, donate_argnums=build.donate)
        lowered = jitted.lower(*build.args)
        t_lower = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0

    cost = compiled.cost_analysis() or {}
    try:
        mem = compiled.memory_analysis()
        mem_rec = {
            k: int(getattr(mem, k))
            for k in (
                "argument_size_in_bytes",
                "output_size_in_bytes",
                "temp_size_in_bytes",
                "alias_size_in_bytes",
                "generated_code_size_in_bytes",
            )
            if hasattr(mem, k)
        }
    except Exception:
        mem_rec = {}
    hlo = compiled.as_text()
    coll = hlo_analysis.collective_bytes(hlo)
    op_counts = hlo_analysis.count_ops(
        hlo, ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
              "collective-permute", "fusion", "while", "custom-call"),
    )
    from benchmarks import hlo_walk

    walk = hlo_walk.analyze(hlo)
    record = {
        "cell": cell.name,
        "arch": cell.arch,
        "shape": cell.shape,
        "kind": cell.kind,
        "mesh": dict(mesh.shape),
        "n_devices": int(mesh.devices.size),
        "model_flops": build.model_flops,
        "note": build.note,
        "lower_s": t_lower,
        "compile_s": t_compile,
        "cost_analysis": {k: float(v) for k, v in cost.items()
                          if isinstance(v, (int, float))},
        "memory_analysis": mem_rec,
        "collective_bytes": coll,
        "hlo_walk": walk,  # loop-corrected per-device totals (hlo_walk.py)
        "op_counts": op_counts,
        "hlo_lines": hlo.count("\n"),
    }
    if save_hlo:
        record["hlo_text"] = hlo
    if hlo_gz_path:
        import gzip

        with gzip.open(hlo_gz_path, "wt") as f:
            f.write(hlo)
    return record


def demo_swa(outdir: str) -> int:
    """Sub-quadratic long-context demo: 524,288-token forward+loss with
    sliding-window attention, lowered on the single-pod mesh."""
    import json

    import jax.numpy as jnp

    from benchmarks import hlo_walk
    from repro.configs import overrides
    from repro.configs.stablelm_12b import CFG
    from repro.models import transformer as tf

    cfg = overrides.apply(CFG, ["attn_window=8192", "kv_block=4096"])
    mesh = make_production_mesh(multi_pod=False)
    p_abs = tf.abstract_params(cfg)
    p_log = tf.param_logical(cfg)
    batch = {
        "tokens": jax.ShapeDtypeStruct((1, 524288), jnp.int32),
        "labels": jax.ShapeDtypeStruct((1, 524288), jnp.int32),
    }
    b_log = {"tokens": ("batch", None), "labels": ("batch", None)}
    in_sh = (
        tree_shardings(p_log, p_abs, mesh),
        tree_shardings(b_log, batch, mesh),
    )
    import time

    t0 = time.perf_counter()
    with mesh:
        compiled = (
            jax.jit(lambda p, b: tf.loss_fn(p, cfg, b), in_shardings=in_sh)
            .lower(p_abs, batch)
            .compile()
        )
    walk = hlo_walk.analyze(compiled.as_text())
    rec = {
        "cell": "stablelm-12b-swa/long_500k (NON-SCORED demo)",
        "window": cfg.attn_window,
        "compile_s": time.perf_counter() - t0,
        "hlo_walk": walk,
        "note": "sub-quadratic sliding-window variant; scored long_500k "
        "cells remain SKIP per the brief",
    }
    os.makedirs(outdir, exist_ok=True)
    path = os.path.join(outdir, "demo_swa_long500k.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=2)
    print(f"[demo-swa] compiled in {rec['compile_s']:.1f}s; "
          f"flops/dev {walk['flops']:.3e}; wrote {path}")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=("single", "multi", "both"), default="both")
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument(
        "--demo-swa", action="store_true",
        help="lower the opt-in sliding-window long-context variant "
        "(stablelm-12b, 524k tokens, window 8192) — NON-SCORED demo; the "
        "assigned full-attention archs keep their mandated long_500k SKIP",
    )
    args = ap.parse_args()

    if args.demo_swa:
        return demo_swa(args.out)

    cells = registry.all_cells()
    if args.arch:
        cells = [c for c in cells if c.arch == args.arch]
    if args.shape:
        cells = [c for c in cells if c.shape == args.shape]
    if args.list:
        for c in cells:
            status = f"SKIP ({c.skip_reason})" if c.build is None else "run"
            print(f"{c.name:45s} {c.kind:10s} {status}")
        return 0

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi", make_production_mesh(multi_pod=True)))

    failures = []
    for mesh_name, mesh in meshes:
        outdir = os.path.join(args.out, mesh_name)
        os.makedirs(outdir, exist_ok=True)
        print(f"=== {mesh_name}: {describe(mesh)} ===", flush=True)
        for cell in cells:
            path = os.path.join(outdir, f"{cell.arch}__{cell.shape}.json")
            if cell.build is None:
                rec = {
                    "cell": cell.name, "arch": cell.arch, "shape": cell.shape,
                    "kind": cell.kind, "skipped": True,
                    "skip_reason": cell.skip_reason,
                }
                with open(path, "w") as f:
                    json.dump(rec, f, indent=2)
                print(f"[skip] {cell.name}: {cell.skip_reason[:80]}", flush=True)
                continue
            if os.path.exists(path) and not args.force:
                print(f"[cached] {cell.name}", flush=True)
                continue
            print(f"[lower+compile] {cell.name} ...", flush=True)
            try:
                rec = run_cell(
                    cell, mesh, save_hlo=args.save_hlo,
                    hlo_gz_path=path.replace(".json", ".hlo.gz"),
                )
                with open(path, "w") as f:
                    json.dump(rec, f, indent=2)
                ca = rec["cost_analysis"]
                print(
                    f"  ok: compile {rec['compile_s']:.1f}s  "
                    f"flops/dev {ca.get('flops', float('nan')):.3e}  "
                    f"coll {rec['collective_bytes']['total']:.3e}B",
                    flush=True,
                )
            except Exception as e:  # noqa: BLE001 — record and continue
                failures.append((mesh_name, cell.name, repr(e)))
                with open(path + ".err", "w") as f:
                    f.write(traceback.format_exc())
                print(f"  FAIL: {e!r}", flush=True)

    print(f"\ndone; {len(failures)} failures")
    for m, c, e in failures:
        print(f"  {m} {c}: {e[:120]}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
