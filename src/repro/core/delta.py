"""Delta algebra for incremental enumeration over dynamic graphs
(DESIGN.md §8).

The paper's targets (biochemical / social networks) mutate constantly; Das
et al. (PAPERS.md, arXiv 1807.09417 / 2001.11433) maintain enumerations
under edge edit streams instead of recomputing.  This module holds the
host-side pieces of that machinery:

* :class:`GraphDelta` — the *effective* edit set of one
  ``SubgraphIndex.update()`` call: added / removed ``(u, v, elab)`` arc
  triples after insert∩remove cancellation and no-op filtering, plus the
  version/fingerprint pair tying it to exactly one index transition.
* :func:`apply_delta` — set-semantics host-graph edit (the test/oracle
  twin of the index's bitmap patching).
* :func:`build_anchor_seeds` — edge-centric seeding: for an anchor pattern
  edge ``(pa, pb, l)`` and its anchor plan (ordering forced to start
  ``pa, pb``), every compatible inserted target edge becomes one engine
  seed entry whose candidate bitmap is pinned to the edge's head.
* :func:`filter_new_matches` — the max-inserted-edge-index dedup rule: a
  new match is credited to exactly one (anchor, inserted-edge) pair — the
  highest-indexed inserted edge it uses — which is equivalent to
  enumerating the insertions one at a time on the growing graph.
* :class:`DeltaMatchSet` — the result of ``Enumerator.run_delta``:
  invalidated old mappings + new mappings, with ``apply()`` producing the
  full post-update match list the conformance gate compares against a
  fresh enumeration.

Mappings here are **node-indexed** (``m[pattern_node] = target_node``),
not ordering-position-indexed: anchor plans use per-anchor orderings, so
position space is not comparable across plans.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.graph import Graph, WORD_BITS, bitmap_from_indices
from repro.core.plan import SearchPlan

EdgeTriple = Tuple[int, int, int]  # (src, dst, edge_label)


def normalize_edges(
    edges: Iterable[Union[Tuple[int, int], EdgeTriple]],
) -> Tuple[EdgeTriple, ...]:
    """Canonicalize an edit list to sorted, distinct ``(u, v, elab)`` arc
    triples (2-tuples get edge label 0).  Arcs are directed: an undirected
    edit must pass both ``(u, v)`` and ``(v, u)``."""
    out = set()
    for e in edges:
        if len(e) == 2:
            u, v = e
            l = 0
        else:
            u, v, l = e
        out.add((int(u), int(v), int(l)))
    return tuple(sorted(out))


@dataclasses.dataclass(frozen=True)
class GraphDelta:
    """The effective edit set of one index update (DESIGN.md §8).

    ``added`` / ``removed`` hold only arcs that actually changed the edge
    set: insert∩remove of the same arc in one update cancels, duplicate
    inserts and removals of absent arcs drop out.  The version/fingerprint
    pairs pin the delta to exactly one ``old index → new index``
    transition — ``Enumerator.run_delta`` refuses a query prepared against
    any other version.
    """

    added: Tuple[EdgeTriple, ...]
    removed: Tuple[EdgeTriple, ...]
    old_version: int
    new_version: int
    old_fingerprint: str
    new_fingerprint: str

    @property
    def is_empty(self) -> bool:
        return not self.added and not self.removed


def apply_delta(
    g: Graph,
    added: Iterable[EdgeTriple] = (),
    removed: Iterable[EdgeTriple] = (),
) -> Graph:
    """Set-semantics edit of a host :class:`Graph`: the distinct arc-triple
    set minus ``removed`` plus ``added``; nodes and node labels unchanged.
    The host twin of the index's bitmap patching — conformance tests build
    the "fresh recompute" side with this."""
    triples = set(zip(g.src.tolist(), g.dst.tolist(), g.edge_labels.tolist()))
    triples -= set(normalize_edges(removed))
    triples |= set(normalize_edges(added))
    es = sorted(triples)
    return Graph.from_edges(
        g.n,
        [(u, v) for (u, v, _) in es],
        labels=g.labels,
        edge_labels=[l for (_, _, l) in es],
    )


# ---------------------------------------------------------------------------
# mappings: canonical node-indexed form, invalidation, dedup
# ---------------------------------------------------------------------------

def pattern_edge_triples(pattern: Graph) -> Tuple[EdgeTriple, ...]:
    """Distinct ``(pa, pb, elab)`` arc triples of the pattern, sorted."""
    return tuple(sorted(set(
        zip(pattern.src.tolist(), pattern.dst.tolist(), pattern.edge_labels.tolist())
    )))


def as_node_mappings(old) -> List[Tuple[int, ...]]:
    """Coerce prior matches to node-indexed tuples.

    Accepts a ``MatchSet`` (position-indexed ``mappings()`` are permuted
    through its ``plan.order``), a ``[M, n_p]`` array, or an iterable of
    node-indexed tuples."""
    if hasattr(old, "mappings") and hasattr(old, "plan"):
        order = [int(x) for x in old.plan.order[: old.plan.n_p]]
        out = []
        for row in old.mappings():
            nm = [0] * len(order)
            for i, t in enumerate(row):
                nm[order[i]] = int(t)
            out.append(tuple(nm))
        return out
    if isinstance(old, np.ndarray):
        return [tuple(r) for r in old.tolist()]
    if isinstance(old, list) and all(isinstance(m, tuple) for m in old):
        return old  # already node-indexed int tuples: no per-element coercion
    return [tuple(int(x) for x in m) for m in old]


def as_mapping_array(old) -> np.ndarray:
    """Array twin of :func:`as_node_mappings`: ``[M, n_p]`` int64 rows.

    The maintained-set hot path (``Enumerator.run_delta`` over a long edit
    stream) keeps prior matches in this form so invalidation is pure numpy
    with no per-tuple coercion; an empty input yields ``[0, 0]``."""
    if isinstance(old, np.ndarray):
        return np.ascontiguousarray(old, dtype=np.int64)
    maps = as_node_mappings(old)
    if not maps:
        return np.zeros((0, 0), dtype=np.int64)
    return np.asarray(maps, dtype=np.int64)


def invalidated_mappings(
    pattern: Graph,
    old_maps: Sequence[Tuple[int, ...]],
    removed: Iterable[EdgeTriple],
) -> List[Tuple[int, ...]]:
    """Old matches killed by the removals: a match dies iff some pattern
    edge's image ``(m[pa], m[pb], l)`` is a removed arc (membership test —
    no re-enumeration; non-induced semantics make this exact).  Vectorized
    over the match set: one ``isin`` per pattern edge on integer-encoded
    arcs, so a step over a large maintained set stays O(|old| · m_p) numpy
    work rather than python tuple hashing."""
    rem = sorted(set(removed))
    if not rem or not len(old_maps):
        return []
    pe = pattern_edge_triples(pattern)
    M = np.asarray(old_maps, dtype=np.int64)
    # encode (u, v, l) injectively: base strictly above every value seen
    B = int(max(
        M.max(),
        max(x for t in rem for x in t),
        max(l for (_, _, l) in pe),
    )) + 2
    rem_codes = np.asarray([(u * B + v) * B + l for (u, v, l) in rem],
                           dtype=np.int64)
    kill = np.zeros(len(M), dtype=bool)
    for (u, v, l) in pe:
        kill |= np.isin((M[:, u] * B + M[:, v]) * B + l, rem_codes)
    return [tuple(r) for r in M[kill].tolist()]


def filter_new_matches(
    pattern: Graph,
    node_maps: Sequence[Tuple[int, ...]],
    added: Sequence[EdgeTriple],
    anchor: EdgeTriple,
) -> List[Tuple[int, ...]]:
    """The max-inserted-edge-index dedup rule.

    A new match may use several inserted arcs and is found once per
    (anchor pattern edge, inserted arc) pair; keep it only in the run
    whose anchor image is the **highest-indexed** inserted arc it uses.
    Injectivity makes pattern-edge images distinct, so exactly one pair
    wins — equivalent to inserting the arcs one at a time and counting
    matches new at each step (Das et al.'s edge-at-a-time view).
    """
    aidx = {t: i for i, t in enumerate(added)}
    pe = pattern_edge_triples(pattern)
    pa, pb, al = anchor
    kept = []
    for m in node_maps:
        ai = aidx.get((m[pa], m[pb], al))
        if ai is None:
            continue  # anchor image not inserted (cannot happen for seeds)
        used = [aidx[img] for (u, v, l) in pe if (img := (m[u], m[v], l)) in aidx]
        if ai == max(used):
            kept.append(m)
    return kept


def canonical_mappings(
    plan: SearchPlan, rows: np.ndarray
) -> List[Tuple[int, ...]]:
    """Position-indexed match-buffer rows ``[K, >=n_p]`` → node-indexed
    tuples via the plan's ordering."""
    order = [int(x) for x in plan.order[: plan.n_p]]
    out = []
    for row in np.asarray(rows):
        nm = [0] * len(order)
        for i in range(len(order)):
            nm[order[i]] = int(row[i])
        out.append(tuple(nm))
    return out


# ---------------------------------------------------------------------------
# edge-centric seeding
# ---------------------------------------------------------------------------

def _bit(bits: np.ndarray, v: int) -> bool:
    return bool((int(bits[v // WORD_BITS]) >> (v % WORD_BITS)) & 1)


def build_anchor_seeds(
    plan: SearchPlan,
    anchor: EdgeTriple,
    added: Sequence[EdgeTriple],
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Engine seeds pinning anchor pattern edge ``(pa, pb, l)`` onto each
    compatible inserted arc (DESIGN.md §8).

    ``plan`` must be the anchor plan: ordering starts ``pa, pb`` (just
    ``pa`` for a self-loop anchor).  Per inserted arc ``(tu, tv, l)``:

    * non-loop anchor — a depth-1 entry mapping position 0 to ``tu`` whose
      candidate bitmap is ``{tv}``, emitted iff ``tu`` passes the position-0
      candidate check and ``tv`` the position-1 check (the engine trusts
      stored candidate bits, so seeds are pre-validated with
      `repro.core.extend.host_cand_bitmap` — exactly the engine's formula,
      anchor-edge adjacency included);
    * self-loop anchor (``pa == pb``, needs ``tu == tv``) — a depth-0
      entry with candidate ``{tu}`` ∩ the position-0 check.

    Returns ``(depth [K], map [K, p_pad], cand [K, w])``.
    """
    from repro.core.extend import host_cand_bitmap

    pa, pb, al = anchor
    p_pad, w, n_t = plan.p_pad, plan.w, plan.n_t
    empty = np.full(p_pad, -1, dtype=np.int32)
    depths: List[int] = []
    maps: List[np.ndarray] = []
    cands: List[np.ndarray] = []
    if plan.satisfiable:
        loop = pa == pb
        assert int(plan.order[0]) == pa, "anchor plan must order pa first"
        if not loop:
            assert int(plan.order[1]) == pb, "anchor plan must order pb second"
        cand0 = host_cand_bitmap(plan, 0, empty)
        for (tu, tv, tl) in added:
            if tl != al:
                continue
            if loop:
                if tu != tv or not _bit(cand0, tu):
                    continue
                depths.append(0)
                maps.append(empty)
                cands.append(bitmap_from_indices(np.array([tu]), n_t, w))
            else:
                if tu == tv or not _bit(cand0, tu):
                    continue
                m = empty.copy()
                m[0] = tu
                if not _bit(host_cand_bitmap(plan, 1, m), tv):
                    continue
                depths.append(1)
                maps.append(m)
                cands.append(bitmap_from_indices(np.array([tv]), n_t, w))
    if not depths:
        return (
            np.zeros(0, dtype=np.int32),
            np.zeros((0, p_pad), dtype=np.int32),
            np.zeros((0, w), dtype=np.uint32),
        )
    return (
        np.asarray(depths, dtype=np.int32),
        np.stack(maps).astype(np.int32),
        np.stack(cands).astype(np.uint32),
    )


# ---------------------------------------------------------------------------
# DeltaMatchSet — the run_delta result
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DeltaMatchSet:
    """Result of ``Enumerator.run_delta``: the match-set *difference*.

    ``added`` / ``removed`` are sorted node-indexed mappings; ``matches``
    is the post-update total; :meth:`apply` materializes the post-update
    match list from the prior one (the ``old ⊕ delta`` side of the
    conformance identity ``full(G±e) == old ⊕ delta(±e)``).
    """

    name: str
    added: List[Tuple[int, ...]]
    removed: List[Tuple[int, ...]]
    n_old: int
    states: int
    n_seeds: int
    n_anchors: int
    preprocess_s: float
    match_s: float
    retries: int = 0
    delta: Optional[GraphDelta] = None

    @property
    def matches(self) -> int:
        return self.n_old - len(self.removed) + len(self.added)

    def apply(self, old) -> List[Tuple[int, ...]]:
        """Post-update node-indexed match list: old minus invalidated plus
        new, sorted."""
        rm = set(self.removed)
        out = [m for m in as_node_mappings(old) if m not in rm]
        out.extend(self.added)
        return sorted(out)

    def apply_array(self, old: np.ndarray) -> np.ndarray:
        """Array twin of :meth:`apply`: lexicographically sorted
        ``[M, n_p]`` int64 rows, kept vectorized so a long edit stream can
        maintain a large match set without per-step tuple churn."""
        old = as_mapping_array(old)
        n_p = old.shape[1] if old.size else (
            len(self.added[0]) if self.added else len(self.removed[0])
        )
        if self.removed and len(old):
            rm = np.asarray(self.removed, dtype=np.int64)
            kill = np.zeros(len(old), dtype=bool)
            for r in rm:  # |removed| is delta-sized; each test is one pass
                kill |= (old == r).all(axis=1)
            old = old[~kill]
        parts = [old.reshape(-1, n_p)]
        if self.added:
            parts.append(np.asarray(self.added, dtype=np.int64))
        out = np.concatenate(parts, axis=0)
        return out[np.lexsort(out.T[::-1])] if len(out) else out
