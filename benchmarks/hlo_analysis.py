"""HLO text analysis: collective-communication byte accounting.

``cost_analysis()`` does not expose collective traffic, so we parse the
compiled HLO text: every instruction definition is indexed (name → shape →
bytes), then each collective op's *operand* bytes are summed per collective
kind.  Used by the dry-run recorder and §Roofline.
"""

from __future__ import annotations

import re
from typing import Dict, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

COLLECTIVE_KINDS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
    "collective-broadcast",
)

# `%name = shape op-name(operands...)`
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\))|(?:[\w\[\],{}\/ ]+?))\s+([\w\-]+)(?:\.\d+)?\("
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OPERAND_RE = re.compile(r"%?([\w.\-]+)")


def shape_bytes(shape_str: str) -> int:
    """Total bytes of one (possibly tuple) HLO shape string."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-kind summed operand bytes of every collective instruction.

    Returns ``{kind: bytes, ..., "total": bytes}`` (per-device program —
    multiply by device count for fleet-wide traffic).
    """
    defs: Dict[str, int] = {}
    out = {k: 0 for k in COLLECTIVE_KINDS}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, shape_str, op = m.group(1), m.group(2), m.group(3)
        nbytes = shape_bytes(shape_str)
        defs[name] = nbytes
        base_op = op
        for kind in COLLECTIVE_KINDS:
            if base_op == kind or base_op.startswith(kind + "-start"):
                # operand list: text between the first '(' after op and ')'
                try:
                    args_part = line.split(op + "(", 1)[1]
                except IndexError:
                    args_part = ""
                depth, buf = 1, []
                for ch in args_part:
                    if ch == "(":
                        depth += 1
                    elif ch == ")":
                        depth -= 1
                        if depth == 0:
                            break
                    buf.append(ch)
                args = "".join(buf)
                ops_bytes = 0
                for om in _OPERAND_RE.finditer(args):
                    ops_bytes += defs.get(om.group(1), 0)
                if ops_bytes == 0:
                    ops_bytes = nbytes  # fallback: output size
                out[kind] += ops_bytes
                break
    out["total"] = sum(out[k] for k in COLLECTIVE_KINDS)
    return out


def count_ops(hlo_text: str, op_names: Tuple[str, ...]) -> Dict[str, int]:
    """Instruction count per op name (e.g. detecting redundant collectives)."""
    counts = {k: 0 for k in op_names}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if m:
            op = m.group(3)
            for k in op_names:
                if op == k or op.startswith(k):
                    counts[k] += 1
    return counts
