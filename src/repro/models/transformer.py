"""Decoder-only LM transformer: GQA + RoPE + (dense | MoE) FFN.

Covers all five assigned LM architectures (grok-1, kimi-k2, nemotron-4,
minitron, stablelm) through one config.  Layers are scanned (stacked params,
``lax.scan``) with per-layer remat — essential both for HBM at train time
and for keeping the 512-device dry-run HLO small.

Entry points:
  * ``forward(params, cfg, tokens)``                 → final hidden states
  * ``loss_fn(params, cfg, batch)``                  → (loss, metrics)
  * ``prefill(params, cfg, tokens)``                 → (last-pos logits, KV cache)
  * ``decode_step(params, cfg, cache, tokens, pos)`` → (logits, new cache)

Sharding is declared via logical axes (distributed/shardings.py): FSDP on
model dims over ``('pod','data')``, tensor parallel on heads / d_ff / vocab /
experts over ``'model'``; the decode KV cache is sequence-sharded over
``'model'`` (flash-decoding via GSPMD).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed.shardings import constraint
from repro.models import moe as moe_mod
from repro.models.attention import apply_rope, blockwise_attention, windowed_attention
from repro.models.common import (
    ACTIVATIONS,
    ParamSpec,
    abstract_from_specs,
    dot,
    init_from_specs,
    logical_from_specs,
    rms_norm,
)


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0  # 0 -> d_model // n_heads
    activation: str = "swiglu"  # swiglu | gelu | squared_relu | silu
    moe: Optional[moe_mod.MoEConfig] = None
    rope_theta: float = 10000.0
    max_seq_len: int = 32768
    attn_window: int = 0  # >0 enables sliding-window attention (opt-in
    # long-context variant; assigned archs are full-attention, see DESIGN §4)
    dtype: Any = jnp.bfloat16
    loss_chunk: int = 2048
    kv_block: int = 1024
    remat: bool = True
    aux_loss_weight: float = 0.01

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def gated(self) -> bool:
        return self.activation == "swiglu"

    def param_count(self) -> int:
        """Total parameters (for 6·N·D roofline bookkeeping)."""
        d, h, kh, dh = self.d_model, self.n_heads, self.n_kv_heads, self.head_dim
        attn = d * h * dh + 2 * d * kh * dh + h * dh * d
        if self.moe:
            m = self.moe
            ffn = d * m.n_experts + 3 * m.n_experts * d * m.d_ff_expert
            ffn += 3 * m.n_shared_experts * d * m.d_ff_expert
        else:
            ffn = (3 if self.gated else 2) * d * self.d_ff
        per_layer = attn + ffn + 2 * d
        return self.n_layers * per_layer + 2 * self.vocab_size * d + d

    def active_param_count(self) -> int:
        """Activated parameters per token (MoE: top-k + shared experts)."""
        if not self.moe:
            return self.param_count()
        d = self.d_model
        m = self.moe
        h, kh, dh = self.n_heads, self.n_kv_heads, self.head_dim
        attn = d * h * dh + 2 * d * kh * dh + h * dh * d
        ffn = d * m.n_experts + 3 * (m.top_k + m.n_shared_experts) * d * m.d_ff_expert
        per_layer = attn + ffn + 2 * d
        return self.n_layers * per_layer + 2 * self.vocab_size * d + d


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------

def param_specs(cfg: LMConfig) -> Dict[str, Any]:
    d, h, kh, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    l, v, f = cfg.n_layers, cfg.vocab_size, cfg.d_ff
    dt = cfg.dtype
    layers: Dict[str, ParamSpec] = {
        "g1": ParamSpec((l, d), (None, None), dt, init="ones"),
        "g2": ParamSpec((l, d), (None, None), dt, init="ones"),
        "wq": ParamSpec((l, d, h, dh), (None, "fsdp", "tensor", None), dt),
        "wk": ParamSpec((l, d, kh, dh), (None, "fsdp", "tensor", None), dt),
        "wv": ParamSpec((l, d, kh, dh), (None, "fsdp", "tensor", None), dt),
        "wo": ParamSpec((l, h, dh, d), (None, "tensor", None, "fsdp"), dt),
    }
    if cfg.moe:
        m = cfg.moe
        e, fe = m.n_experts, m.d_ff_expert
        # §Perf iter 3: small expert counts (grok: 8) do not divide the
        # 16-way model axis, so expert-dim sharding degrades to replication
        # (~19 GB/device of expert weights).  Below 64 experts, tensor-shard
        # the per-expert FFN dim instead.
        if e >= 64:
            log_gate = (None, "expert", "fsdp", None)
            log_down = (None, "expert", None, "fsdp")
        else:
            log_gate = (None, None, "fsdp", "tensor")
            log_down = (None, None, "tensor", "fsdp")
        layers.update(
            router=ParamSpec((l, d, e), (None, "fsdp", None), jnp.float32),
            we_gate=ParamSpec((l, e, d, fe), log_gate, dt),
            we_up=ParamSpec((l, e, d, fe), log_gate, dt),
            we_down=ParamSpec((l, e, fe, d), log_down, dt),
        )
        if m.n_shared_experts:
            fs = m.n_shared_experts * fe
            layers.update(
                ws_gate=ParamSpec((l, d, fs), (None, "fsdp", "tensor"), dt),
                ws_up=ParamSpec((l, d, fs), (None, "fsdp", "tensor"), dt),
                ws_down=ParamSpec((l, fs, d), (None, "tensor", "fsdp"), dt),
            )
    else:
        if cfg.gated:
            layers["w_gate"] = ParamSpec((l, d, f), (None, "fsdp", "tensor"), dt)
        layers["w_up"] = ParamSpec((l, d, f), (None, "fsdp", "tensor"), dt)
        layers["w_down"] = ParamSpec((l, f, d), (None, "tensor", "fsdp"), dt)
    return {
        "embed": ParamSpec((v, d), ("tensor", "fsdp"), dt, scale=1.0),
        "layers": layers,
        "final_norm": ParamSpec((d,), (None,), dt, init="ones"),
        "lm_head": ParamSpec((d, v), ("fsdp", "tensor"), dt),
    }


def abstract_params(cfg: LMConfig):
    return abstract_from_specs(param_specs(cfg))


def param_logical(cfg: LMConfig):
    return logical_from_specs(param_specs(cfg))


def init_params(rng: jax.Array, cfg: LMConfig):
    return init_from_specs(rng, param_specs(cfg))


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _gather_w(w: jnp.ndarray, logical) -> jnp.ndarray:
    """Use-site weight gathering (§Perf iter 9).

    FSDP-sharded weights fed straight into a matmul make GSPMD contract over
    the sharded dim — i.e. partial-sum ALL-REDUCES of [B,S,F] activations
    (observed: 6 fp32 activation all-reduces per layer + full-logit
    all-reduces in the loss).  Constraining the weight to its FSDP-free
    layout at the use site forces the cheap weight all-gather instead
    (ZeRO-3 semantics: gather params, compute locally, reduce-scatter
    grads)."""
    return constraint(w, logical)


def _ffn_dense(x: jnp.ndarray, lp: Dict[str, jnp.ndarray], cfg: LMConfig) -> jnp.ndarray:
    w_up = _gather_w(lp["w_up"], (None, "tensor"))
    w_down = _gather_w(lp["w_down"], ("tensor", None))
    if cfg.gated:
        gate = dot(x, _gather_w(lp["w_gate"], (None, "tensor")))
        up = dot(x, w_up)
        hidden = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    else:
        act = ACTIVATIONS[cfg.activation]
        hidden = act(dot(x, w_up).astype(jnp.float32)).astype(x.dtype)
    hidden = constraint(hidden, ("batch", None, "tensor"))
    return dot(hidden, w_down)


def _ffn_moe(x: jnp.ndarray, lp: Dict[str, jnp.ndarray], cfg: LMConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    b, s, d = x.shape
    flat = x.reshape(b * s, d)
    out, aux = moe_mod.moe_ffn(
        flat, lp["router"], lp["we_gate"], lp["we_up"], lp["we_down"], cfg.moe
    )
    if cfg.moe.n_shared_experts:
        gate = dot(flat, _gather_w(lp["ws_gate"], (None, "tensor")))
        up = dot(flat, _gather_w(lp["ws_up"], (None, "tensor")))
        hidden = jax.nn.silu(gate.astype(jnp.float32)).astype(flat.dtype) * up
        out = out + dot(hidden, _gather_w(lp["ws_down"], ("tensor", None)))
    return out.reshape(b, s, d), aux


def _attention(
    x: jnp.ndarray,
    lp: Dict[str, jnp.ndarray],
    cfg: LMConfig,
    positions: jnp.ndarray,
    cache_kv: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
    cache_len: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, Optional[Tuple[jnp.ndarray, jnp.ndarray]]]:
    """GQA attention.  With ``cache_kv`` given, runs incremental decode:
    writes this step's K/V at ``cache_len`` and attends over the cache."""
    wq = _gather_w(lp["wq"], (None, "tensor", None))
    wk = _gather_w(lp["wk"], (None, "tensor", None))
    wv = _gather_w(lp["wv"], (None, "tensor", None))
    q = jnp.einsum("bsd,dhk->bshk", x, wq)
    k = jnp.einsum("bsd,dhk->bshk", x, wk)
    v = jnp.einsum("bsd,dhk->bshk", x, wv)
    q = constraint(q, ("batch", None, "tensor", None))
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cache_kv is not None:
        ck, cv = cache_kv  # [B, S_max, KH, dh]
        ck = lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, cache_len, 0, 0))
        cv = lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, cache_len, 0, 0))
        ck = constraint(ck, ("batch", "seq", None, None))
        cv = constraint(cv, ("batch", "seq", None, None))
        new_cache = (ck, cv)
        out = blockwise_attention(
            q, ck, cv,
            causal=True,
            q_offset=cache_len,
            kv_valid_len=cache_len + q.shape[1],
            kv_block=cfg.kv_block,
        )
    elif cfg.attn_window and q.shape[1] > 1:
        out = windowed_attention(
            q, k, v, window=cfg.attn_window, q_chunk=min(cfg.kv_block, q.shape[1])
        )
    else:
        out = blockwise_attention(q, k, v, causal=True, kv_block=cfg.kv_block)
    wo = _gather_w(lp["wo"], ("tensor", None, None))
    return jnp.einsum("bshk,hkd->bsd", out, wo), new_cache


def _layer(
    cfg: LMConfig,
    carry: Tuple[jnp.ndarray, jnp.ndarray],
    lp: Dict[str, jnp.ndarray],
    positions: jnp.ndarray,
    layer_cache=None,
    cache_len=None,
):
    h, aux = carry
    a, new_cache = _attention(
        rms_norm(h, lp["g1"]), lp, cfg, positions, layer_cache, cache_len
    )
    h = h + a
    h = constraint(h, ("batch", None, None))
    m = rms_norm(h, lp["g2"])
    if cfg.moe:
        f, aux_l = _ffn_moe(m, lp, cfg)
        aux = aux + aux_l
    else:
        f = _ffn_dense(m, lp, cfg)
    h = h + f
    h = constraint(h, ("batch", None, None))
    return (h, aux), new_cache


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------

def forward(
    params, cfg: LMConfig, tokens: jnp.ndarray, positions: Optional[jnp.ndarray] = None
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Token ids -> final (normed) hidden states.  Returns (hidden, aux_loss)."""
    b, s = tokens.shape
    h = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    h = constraint(h, ("batch", None, None))
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def body(carry, lp):
        out, _ = _layer(cfg, carry, lp, positions)
        return out, None

    step = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable) if cfg.remat else body
    (h, aux), _ = lax.scan(step, (h, jnp.zeros((), jnp.float32)), params["layers"])
    return rms_norm(h, params["final_norm"]), aux


def lm_loss(
    hidden: jnp.ndarray, head: jnp.ndarray, labels: jnp.ndarray, chunk: int
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked cross-entropy: logits are materialized ``chunk`` tokens at a
    time (vocab stays tensor-sharded), never as a full [T, V] tensor."""
    b, s, d = hidden.shape
    t = b * s
    hf = hidden.reshape(t, d)
    yf = labels.reshape(t)
    chunk = min(chunk, t)
    n_chunks = (t + chunk - 1) // chunk
    pad = n_chunks * chunk - t
    hf = jnp.pad(hf, ((0, pad), (0, 0)))
    yf = jnp.pad(yf, (0, pad), constant_values=-1)

    head = constraint(head, (None, "tensor"))  # §Perf iter 9: gather FSDP dim

    def one(args):
        hc, yc = args
        hc = constraint(hc, ("batch", None))
        logits = jnp.einsum(
            "td,dv->tv", hc.astype(jnp.float32),
            constraint(head.astype(jnp.float32), (None, "tensor")),
        )
        # §Perf iter 10: without this pin, GSPMD replicated the whole logits
        # matmul on every device inside the loss scan (16× the flops)
        logits = constraint(logits, ("batch", "tensor"))
        logz = jax.nn.logsumexp(logits, axis=-1)
        # §Perf iter 8: gold-logit extraction via mask-sum, NOT
        # take_along_axis — gathering along the tensor-sharded vocab dim made
        # GSPMD all-reduce the full fp32 logits chunk (8.4 GB × chunks × fwd
        # +bwd ≈ 270 GB/step/device of collective on the 256k vocabs); the
        # masked sum reduces over the sharded axis, so only [chunk] scalars
        # cross devices.
        vocab_iota = jnp.arange(logits.shape[1], dtype=jnp.int32)
        onehot = (vocab_iota[None, :] == jnp.maximum(yc, 0)[:, None])
        gold = jnp.sum(jnp.where(onehot, logits, 0.0), axis=1)
        mask = (yc >= 0).astype(jnp.float32)
        return jnp.sum((logz - gold) * mask), jnp.sum(mask)

    sums, cnts = lax.map(
        jax.checkpoint(one),
        (hf.reshape(n_chunks, chunk, d), yf.reshape(n_chunks, chunk)),
    )
    total, count = jnp.sum(sums), jnp.sum(cnts)
    return total / jnp.maximum(count, 1.0), count


def loss_fn(params, cfg: LMConfig, batch: Dict[str, jnp.ndarray]):
    hidden, aux = forward(params, cfg, batch["tokens"])
    loss, count = lm_loss(hidden, params["lm_head"], batch["labels"], cfg.loss_chunk)
    total = loss + (cfg.aux_loss_weight * aux / cfg.n_layers if cfg.moe else 0.0)
    return total, {"lm_loss": loss, "aux_loss": aux, "tokens": count}


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def abstract_cache(cfg: LMConfig, batch: int, max_len: Optional[int] = None):
    s = max_len or cfg.max_seq_len
    shape = (cfg.n_layers, batch, s, cfg.n_kv_heads, cfg.head_dim)
    return (
        jax.ShapeDtypeStruct(shape, cfg.dtype),
        jax.ShapeDtypeStruct(shape, cfg.dtype),
    )


CACHE_LOGICAL = ((None, "batch", "seq", None, None), (None, "batch", "seq", None, None))


def prefill(params, cfg: LMConfig, tokens: jnp.ndarray, max_len: Optional[int] = None):
    """Full-sequence forward that also materializes the KV cache.

    Returns (last-position logits [B, V], cache (k, v) [L, B, S_max, KH, dh]).
    """
    b, s = tokens.shape
    s_max = max_len or cfg.max_seq_len
    h = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    zero_cache = (
        jnp.zeros((b, s_max, cfg.n_kv_heads, cfg.head_dim), cfg.dtype),
        jnp.zeros((b, s_max, cfg.n_kv_heads, cfg.head_dim), cfg.dtype),
    )

    def body(carry, lp):
        out, cache = _layer(cfg, carry, lp, positions, zero_cache, jnp.int32(0))
        return out, cache

    step = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable) if cfg.remat else body
    (h, _), cache = lax.scan(step, (h, jnp.zeros((), jnp.float32)), params["layers"])
    h = rms_norm(h, params["final_norm"])
    logits = jnp.einsum(
        "bd,dv->bv", h[:, -1].astype(jnp.float32), params["lm_head"].astype(jnp.float32)
    )
    return logits, cache


def decode_step(params, cfg: LMConfig, cache, tokens: jnp.ndarray, cache_len: jnp.ndarray):
    """One incremental decode step.

    Args:
      cache: (k, v) each [L, B, S_max, KH, dh].
      tokens: [B, 1] current token ids.
      cache_len: scalar int32 — number of valid cache positions.

    Returns: (logits [B, V], new cache).
    """
    b, s = tokens.shape
    h = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    positions = jnp.broadcast_to(cache_len[None, None], (b, s)).astype(jnp.int32)

    def body(carry, xs):
        lp, lc = xs
        out, new_cache = _layer(cfg, carry, lp, positions, lc, cache_len)
        return out, new_cache

    (h, _), new_cache = lax.scan(
        body, (h, jnp.zeros((), jnp.float32)), (params["layers"], cache)
    )
    h = rms_norm(h, params["final_norm"])
    logits = jnp.einsum(
        "bd,dv->bv", h[:, -1].astype(jnp.float32), params["lm_head"].astype(jnp.float32)
    )
    return logits, new_cache
