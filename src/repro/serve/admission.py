"""Admission control for the always-on service: a bounded global queue
with per-tenant quotas (DESIGN.md §7).

Quota semantics:

* **Per-tenant outstanding cap** (``max_outstanding_per_tenant``): the
  number of a tenant's queries that are queued, coalescing, or in flight.
  Exceeding it rejects **immediately** with :class:`QuotaExceeded` —
  blocking a over-quota tenant would let one client's burst occupy the
  submission path and starve the others, inverting the isolation the
  quota exists to provide.  The slot is released when the query's
  terminal status is delivered (not when it is popped for execution).
* **Global queue depth** (``max_depth``) is the backpressure bound: a
  full queue blocks :meth:`AdmissionQueue.admit` until the dispatcher
  drains space or the submit timeout elapses, then rejects with
  :class:`Backpressure`.  This is load shedding for *everyone* — it says
  the service as a whole is saturated, not that one tenant misbehaves.

The queue itself is FIFO; fairness across tenants comes from the quota
(no tenant can hold more than its cap of the queue), not from reordering.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Any, Callable, Dict, List, Optional


class QuotaExceeded(RuntimeError):
    """A tenant's outstanding-query quota is exhausted (immediate reject)."""


class Backpressure(RuntimeError):
    """The global admission queue stayed full past the submit timeout."""


@dataclasses.dataclass
class Request:
    """One admitted query riding through the service."""

    query: Any                    # repro.core.session.Query
    tenant: str
    stream: Any                   # repro.serve.stream.ResultStream
    collect: int                  # per-worker match-materialization budget
    submitted_at: float
    seq: int = 0                  # admission order (diagnostics)


class AdmissionQueue:
    """Thread-safe bounded FIFO with per-tenant outstanding quotas."""

    def __init__(
        self,
        max_depth: int = 256,
        max_outstanding_per_tenant: int = 64,
        clock: Callable[[], float] = time.monotonic,
    ):
        if max_depth < 1:
            raise ValueError(f"max_depth must be >= 1, got {max_depth}")
        if max_outstanding_per_tenant < 1:
            raise ValueError(
                "max_outstanding_per_tenant must be >= 1, got "
                f"{max_outstanding_per_tenant}"
            )
        self.max_depth = max_depth
        self.max_outstanding_per_tenant = max_outstanding_per_tenant
        self._clock = clock
        self._cond = threading.Condition()
        self._q: collections.deque = collections.deque()
        self._outstanding: Dict[str, int] = collections.defaultdict(int)
        self._seq = 0

    # -- producer side (client threads) ------------------------------------

    def admit(self, req: Request, timeout: Optional[float] = None) -> None:
        """Admit ``req`` or raise.  Quota violations reject immediately;
        a full queue blocks up to ``timeout`` seconds (``None`` = do not
        block) waiting for the dispatcher to drain space."""
        deadline = None if timeout is None else self._clock() + timeout
        with self._cond:
            while True:
                if self._outstanding[req.tenant] >= self.max_outstanding_per_tenant:
                    raise QuotaExceeded(
                        f"tenant {req.tenant!r} has "
                        f"{self._outstanding[req.tenant]} outstanding queries "
                        f"(cap {self.max_outstanding_per_tenant})"
                    )
                if len(self._q) < self.max_depth:
                    break
                remaining = None if deadline is None else deadline - self._clock()
                if remaining is None or remaining <= 0:
                    raise Backpressure(
                        f"admission queue full ({self.max_depth} deep) past "
                        f"submit timeout ({timeout})"
                    )
                self._cond.wait(remaining)
            req.seq = self._seq
            self._seq += 1
            self._outstanding[req.tenant] += 1
            self._q.append(req)
            self._cond.notify_all()

    # -- consumer side (the dispatcher thread) -----------------------------

    def pop(self, timeout: Optional[float] = None) -> List[Request]:
        """Drain every queued request, waiting up to ``timeout`` seconds
        for the first one.  Returns ``[]`` on timeout."""
        with self._cond:
            if not self._q and timeout:
                self._cond.wait(timeout)
            out = list(self._q)
            self._q.clear()
            if out:
                self._cond.notify_all()  # wake blocked submitters
            return out

    def release(self, tenant: str) -> None:
        """A query of ``tenant`` reached its terminal status: free its
        quota slot."""
        with self._cond:
            self._outstanding[tenant] -= 1
            if self._outstanding[tenant] <= 0:
                del self._outstanding[tenant]

    def kick(self) -> None:
        """Wake a blocked :meth:`pop` (shutdown path)."""
        with self._cond:
            self._cond.notify_all()

    # -- gauges ------------------------------------------------------------

    def depth(self) -> int:
        with self._cond:
            return len(self._q)

    def outstanding(self, tenant: Optional[str] = None) -> int:
        with self._cond:
            if tenant is not None:
                return self._outstanding.get(tenant, 0)
            return sum(self._outstanding.values())
