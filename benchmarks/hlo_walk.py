"""Trip-count-aware HLO accounting.

XLA's ``cost_analysis()`` visits each ``while`` body ONCE, so any scanned
model (layers, attention KV blocks, loss chunks) under-reports flops, bytes
and — critically — collective traffic by the loop trip count.  This module
re-derives totals from the compiled HLO text with loop multipliers:

  * computations are parsed into blocks; a call graph is built from
    ``calls=`` / ``condition=`` / ``body=`` attributes;
  * ``while`` trip counts are recovered from the loop-condition computation
    (the largest s32 ``constant(N)`` feeding its compare — scans lower to
    ``iv < N``); dynamic-condition loops get multiplier 1 and are flagged;
  * flops: ``dot`` ops contribute ``2 · prod(out_dims) · prod(contracting
    dims)``, multiplied along the (while-weighted) call chain;
  * bytes: operand + output bytes at fusion/instruction boundaries (the
    standard HBM-traffic approximation), loop-weighted;
  * collectives: operand bytes per kind, loop-weighted.

Validated against ``cost_analysis()`` on loop-free programs (tests).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

COLLECTIVE_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast",
)

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s+->\s+.+\{(\s*/\*.*\*/)?\s*$")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[^=]*?\)?)\s+([\w\-]+)\("
)
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_CALLS = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_COND = re.compile(r"condition=%?([\w.\-]+)")
_BODY = re.compile(r"body=%?([\w.\-]+)")
_CONST = re.compile(r"=\s*s32\[\]\s*constant\((\d+)\)")
_DIMS = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_PARAM_HDR = re.compile(r"%?([\w.\-]+):\s*((?:\([^)]*\))|(?:[\w\[\],{}]+))")


def shape_dims(shape_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in _SHAPE.finditer(shape_str):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        dims = [int(d) for d in m.group(2).split(",") if d]
        out.append((dt, dims))
    return out


def shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in shape_dims(shape_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    op: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    param_shapes: Dict[str, str]


_COMMENT = re.compile(r"/\*.*?\*/")


def parse_computations(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        line = _COMMENT.sub("", line)  # strip /*index=N*/ etc. inside shapes
        if cur is None:
            m = _COMP_HDR.match(line.strip())
            if m:
                name = m.group(2)
                params: Dict[str, str] = {}
                hdr = line[line.find("(") + 1:]
                hdr = hdr[: hdr.rfind("->")]
                for pm in _PARAM_HDR.finditer(hdr):
                    params[pm.group(1)] = pm.group(2)
                cur = Computation(name=name, instrs=[], param_shapes=params)
            continue
        if line.strip().startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR.match(line)
        if m:
            cur.instrs.append(Instr(m.group(1), m.group(2), m.group(3), line))
    if cur is not None:
        comps[cur.name] = cur
    return comps


def _operand_names(line: str, op: str) -> List[str]:
    try:
        rest = line.split(op + "(", 1)[1]
    except IndexError:
        return []
    depth, buf = 1, []
    for ch in rest:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        buf.append(ch)
    args = "".join(buf)
    # strip attribute-ish tokens; operands are %name or bare names before attrs
    names = re.findall(r"%([\w.\-]+)", args)
    if names:
        return names
    return [t.strip() for t in args.split(",") if t.strip() and "=" not in t]


@dataclasses.dataclass
class Account:
    flops: float = 0.0
    bytes: float = 0.0  # XLA cost_analysis convention: operands+outputs fully
    bytes_traffic: float = 0.0  # HBM-traffic-realistic: gather/scatter count
    # only the moved rows (XLA charges the whole table — measured, see tests)
    bytes_min: float = 0.0  # fusion-optimal lower bound: only tensors that
    # MUST round-trip HBM (dot operands/outputs, collective payloads, moved
    # gather/scatter rows) — the realistic TPU estimate; elementwise chains
    # assumed fully fused
    transcendentals: float = 0.0
    collectives: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVE_KINDS}
    )

    def add(self, other: "Account", mult: float = 1.0) -> None:
        self.flops += mult * other.flops
        self.bytes += mult * other.bytes
        self.bytes_traffic += mult * other.bytes_traffic
        self.bytes_min += mult * other.bytes_min
        self.transcendentals += mult * other.transcendentals
        for k in COLLECTIVE_KINDS:
            self.collectives[k] += mult * other.collectives[k]


# ops where the whole-operand convention wildly overstates real HBM traffic
_INDEXING_OPS = ("gather", "dynamic-slice", "scatter", "dynamic-update-slice")


class HloWalker:
    def __init__(self, text: str):
        self.comps = parse_computations(text)
        self.dynamic_loops: List[str] = []
        self._memo: Dict[str, Account] = {}

    # -- trip counts ---------------------------------------------------------
    def trip_count(self, cond_name: str, while_name: str) -> int:
        comp = self.comps.get(cond_name)
        if comp is None:
            return 1
        consts = []
        for ins in comp.instrs:
            for m in _CONST.finditer(ins.line):
                consts.append(int(m.group(1)))
        if not consts:
            self.dynamic_loops.append(while_name)
            return 1
        return max(max(consts), 1)

    # -- per-computation accounting -------------------------------------------
    def _local_defs(self, comp: Computation) -> Dict[str, str]:
        defs = dict(comp.param_shapes)
        for ins in comp.instrs:
            defs[ins.name] = ins.shape
        return defs

    def _has_indexing(self, comp_name: str, depth: int = 0) -> bool:
        """Does this computation (or a callee) contain gather/scatter ops?"""
        if depth > 4:
            return False
        comp = self.comps.get(comp_name)
        if comp is None:
            return False
        for ins in comp.instrs:
            if ins.op in _INDEXING_OPS:
                return True
            for cm in _CALLS.finditer(ins.line):
                if self._has_indexing(cm.group(1), depth + 1):
                    return True
        return False

    def account(self, comp_name: str) -> Account:
        if comp_name in self._memo:
            return self._memo[comp_name]
        comp = self.comps.get(comp_name)
        acct = Account()
        if comp is None:
            self._memo[comp_name] = acct
            return acct
        self._memo[comp_name] = acct  # break cycles defensively
        defs = self._local_defs(comp)
        for ins in comp.instrs:
            op = ins.op
            if op == "while":
                cond = _COND.search(ins.line)
                body = _BODY.search(ins.line)
                trip = self.trip_count(cond.group(1), ins.name) if cond else 1
                if body:
                    acct.add(self.account(body.group(1)), trip)
                if cond:
                    acct.add(self.account(cond.group(1)), trip)
                continue
            # nested calls (fusions, custom-call with to_apply, conditional...)
            for cm in _CALLS.finditer(ins.line):
                acct.add(self.account(cm.group(1)), 1.0)
            if op == "dot":
                out_elems = 1
                for _, dims in shape_dims(ins.shape):
                    for d in dims:
                        out_elems *= d
                contract = 1
                dm = _DIMS.search(ins.line)
                opnames = _operand_names(ins.line, op)
                if dm and opnames:
                    lhs_shape = defs.get(opnames[0], "")
                    sd = shape_dims(lhs_shape)
                    if sd:
                        dims = sd[0][1]
                        for idx in [int(x) for x in dm.group(1).split(",") if x]:
                            if idx < len(dims):
                                contract *= dims[idx]
                acct.flops += 2.0 * out_elems * contract
            elif op in ("exponential", "log", "tanh", "rsqrt", "sqrt", "power"):
                for _, dims in shape_dims(ins.shape):
                    n = 1
                    for d in dims:
                        n *= d
                    acct.transcendentals += n
            # bytes: operands + outputs at instruction boundary (skip
            # pure-control ops to avoid double counting tuples)
            if op not in ("parameter", "tuple", "get-tuple-element", "constant",
                          "while", "bitcast", "copy-start", "copy-done"):
                out_b = shape_bytes(ins.shape)
                opnames = _operand_names(ins.line, op)
                op_sizes = [shape_bytes(defs.get(n, "")) for n in opnames]
                b = out_b + sum(op_sizes)
                acct.bytes += b
                # traffic-realistic variant: indexed reads/writes move only
                # the selected rows, not the whole table operand
                if op in ("gather", "dynamic-slice"):
                    acct.bytes_traffic += 2 * out_b + 64
                    acct.bytes_min += 2 * out_b
                elif op == "dynamic-update-slice":
                    upd = op_sizes[1] if len(op_sizes) > 1 else out_b
                    acct.bytes_traffic += 2 * upd + 64
                    acct.bytes_min += 2 * upd
                elif op == "scatter":
                    upd = sum(op_sizes[2:]) if len(op_sizes) > 2 else out_b
                    idx = op_sizes[1] if len(op_sizes) > 1 else 0
                    acct.bytes_traffic += 2 * upd + idx
                    acct.bytes_min += 2 * upd
                elif op == "dot":
                    acct.bytes_traffic += b
                    acct.bytes_min += b
                elif op == "fusion":
                    callee = _CALLS.search(ins.line)
                    if callee and self._has_indexing(callee.group(1)):
                        # indexing fusion (gather / scan-save DUS wrapped with
                        # converts): real traffic ≈ the moved slice, which is
                        # the smallest non-scalar tensor at the boundary
                        # (gather: the output; DUS: the update operand) —
                        # read + write
                        tensors = [s for s in op_sizes + [out_b] if s > 256]
                        moved = min(tensors) if tensors else out_b
                        acct.bytes_traffic += 2 * moved + 64
                        acct.bytes_min += 2 * moved
                    else:
                        acct.bytes_traffic += b
                else:
                    acct.bytes_traffic += b
            for kind in COLLECTIVE_KINDS:
                if op == kind or op == kind + "-start":
                    cb = 0
                    for name in _operand_names(ins.line, op):
                        cb += shape_bytes(defs.get(name, ""))
                    if cb == 0:
                        cb = shape_bytes(ins.shape)
                    acct.collectives[kind] += cb
                    acct.bytes_min += cb  # collective payloads hit HBM
                    break
        return acct

    def entry(self) -> str:
        # entry computation: the one named in `ENTRY` — parse_computations
        # keeps it like others; find via main-like names
        for name in self.comps:
            if name.startswith("main"):
                return name
        # fallback: computation that is not called by anyone
        called = set()
        for comp in self.comps.values():
            for ins in comp.instrs:
                for m in _CALLS.finditer(ins.line):
                    called.add(m.group(1))
                for m in _COND.finditer(ins.line):
                    called.add(m.group(1))
                for m in _BODY.finditer(ins.line):
                    called.add(m.group(1))
        for name in self.comps:
            if name not in called:
                return name
        return next(iter(self.comps))


def analyze(text: str) -> Dict[str, float]:
    """Loop-corrected per-device totals from compiled HLO text."""
    walker = HloWalker(text)
    acct = walker.account(walker.entry())
    out = {
        "flops": acct.flops,
        "bytes": acct.bytes,
        "bytes_traffic": acct.bytes_traffic,
        "bytes_min": acct.bytes_min,
        "transcendentals": acct.transcendentals,
        "collective_total": sum(acct.collectives.values()),
        "n_dynamic_loops": float(len(walker.dynamic_loops)),
    }
    for k, v in acct.collectives.items():
        out[f"collective_{k}"] = v
    return out
