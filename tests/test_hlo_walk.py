"""HLO walker: loop-corrected accounting must match cost_analysis on
loop-free programs and multiply scan bodies by trip counts."""

import jax
import jax.numpy as jnp
import pytest
from jax import lax

import repro.bench  # noqa: F401  (puts the repo root on sys.path)
from benchmarks import hlo_analysis, hlo_walk  # noqa: E402


def test_flat_matches_cost_analysis():
    x = jnp.ones((64, 64), jnp.float32)

    def f(x):
        return (x @ x) @ (x @ x.T)

    c = jax.jit(f).lower(x).compile()
    ca = c.cost_analysis()
    if isinstance(ca, list):  # older jax returned [dict]
        ca = ca[0]
    aw = hlo_walk.analyze(c.as_text())
    assert aw["flops"] == pytest.approx(ca["flops"], rel=1e-6)
    assert aw["bytes"] == pytest.approx(ca["bytes accessed"], rel=1e-6)


def test_scan_trip_multiplication():
    x = jnp.ones((64, 64), jnp.float32)

    def f(x):
        def body(c, _):
            return c @ c, None

        out, _ = lax.scan(body, x, None, length=8)
        return out

    c = jax.jit(f).lower(x).compile()
    aw = hlo_walk.analyze(c.as_text())
    assert aw["flops"] == pytest.approx(8 * 2 * 64**3, rel=0.01)


def test_nested_scan():
    x = jnp.ones((32, 32), jnp.float32)

    def f(x):
        def outer(c, _):
            def inner(d, _):
                return d @ d, None

            d, _ = lax.scan(inner, c, None, length=5)
            return d, None

        out, _ = lax.scan(outer, x, None, length=3)
        return out

    c = jax.jit(f).lower(x).compile()
    aw = hlo_walk.analyze(c.as_text())
    assert aw["flops"] == pytest.approx(15 * 2 * 32**3, rel=0.01)


def test_dynamic_loop_flagged():
    def f(x):
        def cond(c):
            return jnp.sum(c) < 1e6

        def body(c):
            return c @ c

        return lax.while_loop(cond, body, x)

    x = jnp.full((16, 16), 1.1, jnp.float32)
    c = jax.jit(f).lower(x).compile()
    aw = hlo_walk.analyze(c.as_text())
    assert aw["n_dynamic_loops"] >= 1
    assert aw["flops"] >= 2 * 16**3  # body counted at least once


def test_collective_regex_kinds():
    text = """
HloModule m
ENTRY %main.1 (p0: f32[128]) -> f32[128] {
  %p0 = f32[128]{0} parameter(0)
  %ar = f32[128]{0} all-reduce(%p0), replica_groups={}, to_apply=%add.1
  ROOT %ag = f32[128]{0} all-gather(%ar), dimensions={0}
}
"""
    cb = hlo_analysis.collective_bytes(text)
    assert cb["all-reduce"] == 512
    assert cb["all-gather"] == 512
    assert cb["total"] == 1024


def test_shape_bytes():
    assert hlo_walk.shape_bytes("f32[10,10]{1,0}") == 400
    assert hlo_walk.shape_bytes("bf16[8]") == 16
    assert hlo_walk.shape_bytes("(s32[], f32[4])") == 20
    assert hlo_walk.shape_bytes("u32[2,2]") == 16
