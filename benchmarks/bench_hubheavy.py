"""Hub-heavy targets: edge-centric seeding + degree-bucketed CSR walk
(DESIGN.md §10).

  PYTHONPATH=src python -m benchmarks.bench_hubheavy            # 33k nodes
  PYTHONPATH=src python -m benchmarks.bench_hubheavy --smoke    # CI-sized

A power-law target with a flat exponent puts a few hub rows of degree
``≈ n_t`` next to a near-isolated tail, so the PR-5 CSR walk — every lane
scanning to the *global* ``deg_cap`` — wastes almost its whole trip count
on tail rows, and the depth-0 vertex root split opens a search tree from
every domain node when only a rare edge class can ever host the pattern's
anchor edge.  This bench runs the tentpole configuration (plan built with
``seed_edge="auto"``, ``root_seeding="edge"``, ``csr_walk="bucketed"``)
against the PR-5 baseline (``root_seeding="vertex"``,
``csr_walk="flat"``) end-to-end and asserts:

* **frontier shrink** (always): the edge-seeded root frontier (arcs of
  the rarest compatible edge class) is ≥ 10× smaller than the vertex
  root frontier (``|dom[0]|``);
* **identity** (always): both runs produce the same match count, equal to
  the sequential reference oracle on the same plan;
* **speedup** (full-size runs only; ``--smoke`` reports without
  asserting): the tentpole run is ≥ 2× faster end-to-end than the
  baseline.  Both sides run the jitted jnp-math walk (``use_pallas``
  off), so the comparison is compiled-vs-compiled and the gate applies
  on any host; a Pallas-interpret configuration would be exempt, but
  this bench never routes the Pallas kernels.

Emits CSV rows, the ``artifacts/bench/hubheavy.json`` artifact, and —
via the shared ``--json PATH`` writer — the committed ``BENCH_9.json``.
"""

from __future__ import annotations

import argparse
import time

import numpy as np

try:
    from benchmarks import common
except ImportError:  # executed from an arbitrary cwd
    import repro.bench  # noqa: F401  (puts the repo root on sys.path)
    from benchmarks import common

from repro.core import EngineConfig, engine as eng, frontier
from repro.core.graph import popcount
from repro.core.plan import build_csr_plan
from repro.core.ref import ref_enumerate
from repro.data import graphgen

HUB_NT = 33_067  # pdbsv1 scale (Table 1), flattened exponent → hub-heavy
SMOKE_NT = 4_000
FRONTIER_FLOOR = 10.0  # edge seeds must shrink the root frontier this much
SPEEDUP_FLOOR = 2.0  # tentpole vs PR-5 baseline, full-size compiled runs


def _timed_run(plan, cfg):
    """Warm (compile + first execution), then one timed run."""
    eng.run(plan, cfg)
    t0 = time.perf_counter()
    res = eng.run(plan, cfg)
    return res, time.perf_counter() - t0


def run(n: int, workers: int = 8, seed: int = 7, smoke: bool = False) -> dict:
    tgt = graphgen.power_law_graph(
        n, avg_deg=4.0, alpha=1.5, n_labels=32, seed=seed,
    )
    deg = tgt.out_degrees() + tgt.in_degrees()
    pat = graphgen.extract_pattern(
        tgt, 6, seed=seed, start=int(np.argsort(deg)[-80]),
    )
    assert pat.m > 0, "pattern extraction degenerated"

    vplan = build_csr_plan(pat, tgt, variant="ri")
    eplan = build_csr_plan(pat, tgt, variant="ri", seed_edge="auto")
    assert eplan.seed_edge is not None

    # --- root frontier: |dom[0]| vertex roots vs seed-class arcs ----------
    vertex_frontier = int(popcount(vplan.dom_bits[0]).sum())
    sd, _, _ = frontier.root_seed_entries(eplan)
    edge_frontier = int(sd.shape[0])
    shrink = vertex_frontier / max(edge_frontier, 1)
    assert shrink >= FRONTIER_FLOOR, (
        f"edge seeding must shrink the root frontier >= {FRONTIER_FLOOR}x: "
        f"{vertex_frontier} vertex roots vs {edge_frontier} edge seeds "
        f"({shrink:.1f}x)"
    )

    # --- end-to-end: PR-5 baseline vs the tentpole configuration ---------
    base_cfg = EngineConfig(n_workers=workers, expand_width=4,
                            step_backend="csr", root_seeding="vertex",
                            csr_walk="flat")
    new_cfg = EngineConfig(n_workers=workers, expand_width=4,
                           step_backend="csr", root_seeding="edge",
                           csr_walk="bucketed")
    base, t_base = _timed_run(vplan, base_cfg)
    new, t_new = _timed_run(eplan, new_cfg)
    assert new.matches == base.matches, (
        f"tentpole run diverged: {new.matches} vs baseline {base.matches}"
    )

    # --- correctness at scale: the sequential reference oracle ------------
    ref = ref_enumerate(pat, tgt, plan=vplan)
    assert (base.matches, base.states) == (ref.matches, ref.states), (
        f"baseline diverged from the oracle: engine=({base.matches}, "
        f"{base.states}) ref=({ref.matches}, {ref.states})"
    )

    # both sides run the jitted jnp-math walk (use_pallas off) — there is no
    # interpret-mode penalty to exempt, so full-size runs assert the gate
    speedup = t_base / max(t_new, 1e-9)
    speedup_asserted = not smoke
    if speedup_asserted:
        assert speedup >= SPEEDUP_FLOOR, (
            f"bucketed walk + edge seeding must be >= {SPEEDUP_FLOOR}x the "
            f"flat-walk vertex-seeded baseline at n_t={n}; measured "
            f"{speedup:.2f}x ({t_base:.3f}s vs {t_new:.3f}s)"
        )

    from repro.core.extend import _pad_deg_cap
    from repro.core.graph import deg_bucket_caps

    caps = deg_bucket_caps(_pad_deg_cap(vplan.csr.deg_cap))
    payload = dict(
        n_t=int(n),
        target_edges=int(tgt.m),
        pattern_nodes=int(pat.n),
        pattern_edges=int(pat.m),
        seed_edge=list(eplan.seed_edge),
        deg_cap=int(vplan.csr.deg_cap),
        bucket_caps=list(caps),
        root_frontier_vertex=vertex_frontier,
        root_frontier_edge=edge_frontier,
        frontier_shrink=shrink,
        matches=int(base.matches),
        states_vertex=int(base.states),
        states_edge=int(new.states),
        flat_wall_s=t_base,
        bucketed_wall_s=t_new,
        speedup=speedup,
        matches_per_sec_flat=base.matches / max(t_base, 1e-9),
        matches_per_sec_bucketed=new.matches / max(t_new, 1e-9),
        speedup_asserted=speedup_asserted,
        ref_verified=True,
        smoke=smoke,
    )
    print(common.csv_row(
        "hubheavy/flat_vertex", t_base * 1e6 / max(base.states, 1),
        f"n_t={n};matches={base.matches};states={base.states};"
        f"wall={t_base:.3f}s",
    ))
    print(common.csv_row(
        "hubheavy/bucketed_edge", t_new * 1e6 / max(new.states, 1),
        f"n_t={n};matches={new.matches};states={new.states};"
        f"wall={t_new:.3f}s;frontier={vertex_frontier}->{edge_frontier};"
        f"speedup={speedup:.2f}x",
    ))
    common.save_json("hubheavy", payload)
    return payload


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--nodes", type=int, default=HUB_NT)
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--smoke", action="store_true",
                    help=f"CI-sized run ({SMOKE_NT} nodes): same frontier "
                    "and identity assertions, speedup reported only")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write the JSON payload to PATH "
                    "(e.g. BENCH_9.json at the repo root)")
    args = ap.parse_args()
    n = SMOKE_NT if args.smoke else args.nodes

    out = run(n, workers=args.workers, seed=args.seed, smoke=args.smoke)
    common.write_json_path(args.json, out)
    verdict = (
        f"(asserted >= {SPEEDUP_FLOOR}x)" if out["speedup_asserted"]
        else "(reported only)"
    )
    print(
        f"\n[hubheavy] n_t={out['n_t']} deg_cap={out['deg_cap']} "
        f"buckets={out['bucket_caps']}: root frontier "
        f"{out['root_frontier_vertex']} -> {out['root_frontier_edge']} "
        f"({out['frontier_shrink']:.1f}x, asserted >= {FRONTIER_FLOOR}x)"
    )
    print(
        f"[hubheavy] {out['matches']} matches (oracle-verified): "
        f"flat+vertex {out['flat_wall_s']:.2f}s "
        f"({out['matches_per_sec_flat']:.0f} matches/s) vs bucketed+edge "
        f"{out['bucketed_wall_s']:.2f}s "
        f"({out['matches_per_sec_bucketed']:.0f} matches/s) = "
        f"{out['speedup']:.2f}x {verdict}"
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
