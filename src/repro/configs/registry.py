"""Architecture × shape cell registry.

Every assigned architecture registers an :class:`Arch` with one
:class:`Cell` per input shape; the dry-run (launch/dryrun.py), roofline
(benchmarks/roofline.py) and smoke tests all walk this registry.

A cell's ``build()`` returns the jit-able step function plus *abstract*
arguments (ShapeDtypeStruct pytrees — never allocated) and matching
logical-axis pytrees, so lowering works for trillion-parameter configs on a
CPU host.  ``model_flops`` is the analytic useful-work estimate used for the
MODEL_FLOPS / HLO_FLOPs ratio in §Roofline.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class CellBuild:
    fn: Callable
    args: Tuple[Any, ...]  # abstract args (pytrees of ShapeDtypeStruct)
    logical: Tuple[Any, ...]  # logical-axis pytrees matching ``args``
    model_flops: float
    note: str = ""
    donate: Tuple[int, ...] = ()


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    kind: str  # train | prefill | decode | serve | retrieval | engine
    build: Optional[Callable[[], CellBuild]]
    skip_reason: Optional[str] = None

    @property
    def name(self) -> str:
        return f"{self.arch}/{self.shape}"


@dataclasses.dataclass
class Arch:
    name: str
    family: str  # lm | gnn | recsys | sge
    cfg: Any
    cells: Dict[str, Cell]
    smoke: Callable[[], Dict[str, float]]  # reduced-config forward/train step
    notes: str = ""


_REGISTRY: Dict[str, Arch] = {}

ARCH_MODULES = [
    "repro.configs.grok_1_314b",
    "repro.configs.kimi_k2_1t_a32b",
    "repro.configs.nemotron_4_15b",
    "repro.configs.minitron_8b",
    "repro.configs.stablelm_12b",
    "repro.configs.gcn_cora",
    "repro.configs.graphcast",
    "repro.configs.schnet",
    "repro.configs.graphsage_reddit",
    "repro.configs.din",
    "repro.configs.sge",  # the paper's own workload (bonus cells)
]


def register(arch: Arch) -> Arch:
    _REGISTRY[arch.name] = arch
    return arch


def get(name: str) -> Arch:
    load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def load_all() -> Dict[str, Arch]:
    for mod in ARCH_MODULES:
        importlib.import_module(mod)
    return dict(_REGISTRY)


def all_cells(include_skipped: bool = True) -> List[Cell]:
    out: List[Cell] = []
    for arch in load_all().values():
        for cell in arch.cells.values():
            if include_skipped or cell.build is not None:
                out.append(cell)
    return out


# ---------------------------------------------------------------------------
# helpers shared by arch config modules
# ---------------------------------------------------------------------------

def round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def abstract_dict(shapes: Dict[str, Tuple[Tuple[int, ...], Any]]):
    """{name: (shape, dtype)} -> ({name: SDS}, template for logical)."""
    return {k: jax.ShapeDtypeStruct(s, d) for k, (s, d) in shapes.items()}
