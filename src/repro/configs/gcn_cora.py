"""gcn-cora — 2L d_hidden=16 aggregator=mean norm=sym.  [arXiv:1609.02907; paper]"""

from repro.configs.gnn_common import GnnModelDef, GnnShape, make_gnn_arch
from repro.models.gnn import gcn

CFG = gcn.GCNConfig(n_layers=2, d_hidden=16, aggregator="mean", norm="sym")


def fwd_flops(cfg: gcn.GCNConfig, shape: GnnShape) -> float:
    dims = [shape.d_feat] + [cfg.d_hidden] * (cfg.n_layers - 1) + [shape.d_out]
    f = 0.0
    for i in range(cfg.n_layers):
        f += 2.0 * shape.n_nodes * dims[i] * dims[i + 1]  # H W
        f += 2.0 * shape.n_edges * dims[i + 1]  # edge msg scale + scatter-add
    return f


ARCH = make_gnn_arch(
    GnnModelDef(
        name="gcn-cora",
        cfg=CFG,
        param_specs=gcn.param_specs,
        forward=lambda params, cfg, batch: gcn.forward(params, cfg, batch),
        fwd_flops=fwd_flops,
        notes="Shares the segment_sum substrate with the SGE engine "
        "(DESIGN.md §4); load is regular full-batch.",
    )
)
