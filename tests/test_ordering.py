"""GreatestConstraintFirst edge cases + edge-centric seed selection
(DESIGN.md §10, satellite coverage for the seeding tentpole).

The ``seed_order=`` prefix is load-bearing for both delta seeding (§8)
and edge seeding (§10) — these tests pin its contract at the corners the
conformance suite's random cases rarely hit: fully symmetric patterns
(every greedy key tied), anchors on zero-degree nodes, and the search-tree
size effect of a seeded ordering on the power-law conformance target.
"""

import numpy as np
import pytest

from repro.core import EngineConfig
from repro.core import engine as eng
from repro.core import ordering as ord_mod
from repro.core.graph import Graph, PackedGraph
from repro.core.plan import build_plan
from tests.conftest import extract_connected_pattern, power_law_target


@pytest.fixture
def rng():
    return np.random.default_rng(0)


# ---------------------------------------------------------------------------
# deterministic tie-breaking
# ---------------------------------------------------------------------------

def test_tie_break_is_node_id_on_symmetric_pattern():
    """On a 4-cycle every node has identical (w_m, w_n, deg) at every
    greedy step — the ordering must still be a fixed function of the
    pattern (smaller node id wins each tie)."""
    cyc = Graph.from_edges(4, [(0, 1), (1, 2), (2, 3), (3, 0)],
                           undirected=True)
    o = ord_mod.greatest_constraint_first(cyc)
    assert o.order.tolist() == [0, 1, 2, 3]
    # stable across repeated invocations (no hidden iteration-order state)
    for _ in range(3):
        assert ord_mod.greatest_constraint_first(cyc).order.tolist() == \
            o.order.tolist()


def test_tie_break_domain_sizes_break_symmetric_ties():
    """Equal greedy keys + distinct domain sizes: the smaller domain wins
    (RI-DS-SI), and equal domain sizes fall back to the id tie-break —
    the full key chain is exercised on one symmetric pattern."""
    cyc = Graph.from_edges(4, [(0, 1), (1, 2), (2, 3), (3, 0)],
                           undirected=True)
    o = ord_mod.greatest_constraint_first(
        cyc, domain_sizes=np.array([9, 9, 9, 2]))
    assert o.order[0] == 3  # first pick: max degree tie → smallest domain
    o2 = ord_mod.greatest_constraint_first(
        cyc, domain_sizes=np.array([5, 5, 5, 5]))
    assert o2.order.tolist() == [0, 1, 2, 3]  # all-tied domains → id order


# ---------------------------------------------------------------------------
# seed_order corner cases
# ---------------------------------------------------------------------------

def test_seed_order_zero_degree_anchor_endpoints():
    """Anchoring isolated (zero-degree) nodes is legal: they head the
    ordering verbatim, contribute no parent constraints anywhere, and the
    connected remainder still orders greedily behind them."""
    pat = Graph.from_edges(5, [(2, 3), (3, 4), (4, 2)], undirected=True)
    # nodes 0 and 1 have degree 0
    o = ord_mod.greatest_constraint_first(pat, seed_order=(1, 0))
    assert o.order.tolist()[:2] == [1, 0]
    assert sorted(o.order.tolist()) == list(range(5))
    assert o.parents[0] == () and o.parents[1] == ()
    # no parent list references the zero-degree positions
    for plist in o.parents:
        for (j, _, _) in plist:
            assert o.order[j] in (2, 3, 4)
    # every directed triangle arc still lands exactly once as a constraint
    assert sum(len(p) for p in o.parents) == 6


def test_seed_order_duplicates_collapse_and_rest_is_greedy():
    """A seed prefix with duplicates collapses to first occurrence; the
    unseeded remainder is ordered exactly as if the prefix were in_order
    already (greedy keys computed against it)."""
    path = Graph.from_edges(4, [(0, 1), (1, 2), (2, 3)], undirected=True)
    o = ord_mod.greatest_constraint_first(path, seed_order=(2, 2, 1))
    assert o.order.tolist()[:2] == [2, 1]
    # 3 and 0: w_m(3)=1 (nbr 2 ordered), w_m(0)=1 (nbr 1 ordered), deg tie,
    # id tie-break → 0 before 3
    assert o.order.tolist() == [2, 1, 0, 3]


def test_seed_order_overrides_singleton_first():
    pat = Graph.from_edges(3, [(0, 1), (1, 2)], undirected=True)
    o = ord_mod.greatest_constraint_first(
        pat, domain_sizes=np.array([4, 4, 1]), singleton_first=True,
        seed_order=(0, 1),
    )
    assert o.order.tolist()[:2] == [0, 1]


# ---------------------------------------------------------------------------
# seeded ordering vs default: search-tree size on the power-law target
# ---------------------------------------------------------------------------

def test_seed_order_state_counts_vs_default_on_power_law(rng):
    """Seeded plans (anchor forced to positions 0/1) and the default RI
    ordering must agree on matches while legitimately differing in visited
    states on the hub-heavy conformance target; the seeded tree must stay
    within a sane blowup bound (anchoring is a reordering, not a rewrite —
    a regression here means parent constraints were dropped)."""
    tgt = power_law_target(rng, 420, avg_deg=3.5, alpha=1.7, n_labels=8)
    pat = extract_connected_pattern(rng, tgt, 4)
    pk = PackedGraph.from_graph(tgt)
    cfg = EngineConfig(n_workers=4, expand_width=2, step_backend="csr")
    base = eng.run(build_plan(pat, pk), cfg)
    edges = sorted({(u, v) for u, v in zip(pat.src.tolist(), pat.dst.tolist())
                    if u != v})
    states = []
    for u, v in edges:
        seeded = eng.run(build_plan(pat, pk, anchor=(u, v)), cfg)
        assert seeded.matches == base.matches
        states.append(seeded.states)
    assert len(states) >= 2
    # anchored orderings explore differently-sized trees but every parent
    # constraint is still applied: bounded blowup, never an empty tree
    assert all(0 < s <= 50 * base.states for s in states)
    assert any(s != base.states for s in states)  # ordering really changed
