"""grok-1-314b — 64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072,
MoE 8 experts top-2.  [hf:xai-org/grok-1; unverified]"""

from repro.configs.lm_common import make_lm_arch
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig

CFG = LMConfig(
    name="grok-1-314b",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32768,
    loss_chunk=65536,  # §Perf iter 2: fewer lm_head re-reads (was 2048)
    vocab_size=131072,
    activation="swiglu",
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32768),
    max_seq_len=32768,
)

SMOKE = LMConfig(
    name="grok-1-314b-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    activation="swiglu",
    moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=128, capacity_round=8),
    max_seq_len=64,
    loss_chunk=16,
    kv_block=8,
)

ARCH = make_lm_arch(CFG, SMOKE, notes="MoE 8e top-2; paper technique N/A "
                    "(dense regular compute); dispatch shares the scheduler's "
                    "coalesce-then-rebalance shape (DESIGN.md §4).")
