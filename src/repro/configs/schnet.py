"""schnet — n_interactions=3 d_hidden=64 rbf=300 cutoff=10.
[arXiv:1706.08566; paper]"""

from repro.configs.gnn_common import GnnModelDef, GnnShape, make_gnn_arch
from repro.models.gnn import schnet

CFG = schnet.SchNetConfig(n_interactions=3, d_hidden=64, n_rbf=300, cutoff=10.0)
SMOKE = schnet.SchNetConfig(n_interactions=2, d_hidden=16, n_rbf=8, cutoff=5.0)


def fwd_flops(cfg: schnet.SchNetConfig, shape: GnnShape) -> float:
    n, e, d = shape.n_nodes, shape.n_edges, cfg.d_hidden
    f = 2.0 * n * shape.d_feat * d  # embed
    per = (
        2.0 * e * cfg.n_rbf * d  # filter MLP layer 0 (edge-wise)
        + 2.0 * e * d * d  # filter MLP layer 1
        + 2.0 * n * d * d  # in_w1
        + e * d  # message modulation + scatter
        + 2.0 * 2.0 * n * d * d  # in_w2, in_w3
    )
    f += cfg.n_interactions * per
    f += 2.0 * n * d * (d // 2) + 2.0 * n * (d // 2) * shape.d_out
    return f


ARCH = make_gnn_arch(
    GnnModelDef(
        name="schnet",
        cfg=CFG,
        param_specs=schnet.param_specs,
        forward=lambda params, cfg, batch: schnet.forward(params, cfg, batch),
        fwd_flops=fwd_flops,
        with_positions=True,
        smoke_cfg=SMOKE,
        notes="Molecular continuous-filter conv; edge-wise filter MLP over "
        "300 RBFs makes this the most edge-bound GNN cell.",
    )
)
