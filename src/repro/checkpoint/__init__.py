"""Fault-tolerant checkpointing: atomic sharded store + elastic reshard."""

from repro.checkpoint import store
from repro.checkpoint.reshard import place

__all__ = ["store", "place"]
