"""Unit tests: graph representations and bitmap helpers."""

import numpy as np
import pytest

from repro.core.graph import (
    Graph,
    PackedGraph,
    bitmap_from_indices,
    bitmap_to_indices,
    n_words,
    popcount,
)


def test_bitmap_roundtrip(rng):
    for n in (1, 31, 32, 33, 100, 1000):
        idx = np.unique(rng.integers(0, n, size=min(n, 37)))
        bits = bitmap_from_indices(idx, n)
        back = bitmap_to_indices(bits)
        assert np.array_equal(np.sort(idx), back)
        assert popcount(bits[None, :])[0] == len(idx)


def test_popcount_matrix(rng):
    bits = rng.integers(0, 2**32, size=(7, 5), dtype=np.uint32)
    expect = np.array(
        [sum(bin(int(w)).count("1") for w in row) for row in bits]
    )
    assert np.array_equal(popcount(bits), expect)


def test_adjacency_bitmaps_directed():
    g = Graph.from_edges(4, [(0, 1), (1, 2), (3, 0)], edge_labels=[0, 1, 0])
    p = PackedGraph.from_graph(g)
    assert p.n_edge_labels == 2
    # out: label 0: 0->1, 3->0
    assert bitmap_to_indices(p.adj_bits[0, 0, 0]).tolist() == [1]
    assert bitmap_to_indices(p.adj_bits[0, 0, 3]).tolist() == [0]
    # label 1: 1->2
    assert bitmap_to_indices(p.adj_bits[1, 0, 1]).tolist() == [2]
    # in rows: adj_in[l, u] bit v iff v->u
    assert bitmap_to_indices(p.adj_bits[0, 1, 1]).tolist() == [0]
    assert bitmap_to_indices(p.adj_bits[1, 1, 2]).tolist() == [1]


def test_degrees_and_neighbors():
    g = Graph.from_edges(3, [(0, 1), (1, 2)], undirected=True)
    assert g.out_degrees().tolist() == [1, 2, 1]
    assert g.in_degrees().tolist() == [1, 2, 1]
    assert set(g.neighbors(1).tolist()) == {0, 2}
    assert g.has_edge(0, 1) and g.has_edge(1, 0) and not g.has_edge(0, 2)


def test_pad_words():
    g = Graph.from_edges(3, [(0, 1)], undirected=True)
    p = PackedGraph.from_graph(g, pad_words_to=128)
    assert p.w == 128
    assert p.adj_bits.shape[-1] == 128
    # padding bits must stay zero
    assert p.adj_bits[:, :, :, 1:].sum() == 0


def test_n_words():
    assert n_words(0) == 1
    assert n_words(1) == 1
    assert n_words(32) == 1
    assert n_words(33) == 2
