"""Ring-buffer frontier stacks: the SoA state layer of the engine
(DESIGN.md §6.1).

Each of ``V`` workers owns a ring-buffer stack of search-tree entries in
dense SoA arrays (:class:`EngineState`): an entry is ``(depth, mapping,
used-bitmap, candidate-bitmap)`` and a task is one candidate bit.  This
module owns everything that touches the *stack structure* — popping the
top ``expand_width`` entries, pushing surviving parents below freshly
created children, ring compaction, and overflow accounting — and knows
nothing about *what* an expansion computes (that is `repro.core.extend`,
behind the ``StepBackend`` seam) or how rounds are driven
(`repro.core.engine`).

All ops are batched over the leading worker axis (no ``vmap``): under
``shard_map`` the caller holds the local ``V / D`` shard and every op here
stays worker-local, so the same code serves the single-device and mesh
paths (DESIGN.md §2.4).
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple, TYPE_CHECKING

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec

from repro.core.graph import WORD_BITS, bitmap_from_indices
from repro.core.plan import SearchPlan

if TYPE_CHECKING:  # engine imports extend imports frontier; avoid the cycle
    from repro.core.engine import EngineConfig


class EngineState(NamedTuple):
    st_depth: jnp.ndarray  # [V, S] int32
    st_map: jnp.ndarray  # [V, S, P] int32
    st_used: jnp.ndarray  # [V, S, W] uint32
    st_cand: jnp.ndarray  # [V, S, W] uint32
    base: jnp.ndarray  # [V] int32 ring-buffer base
    size: jnp.ndarray  # [V] int32
    matches: jnp.ndarray  # [V] int32
    states: jnp.ndarray  # [V] int32
    exp_depth: jnp.ndarray  # [V] int32 summed depth of expanded entries
    steals: jnp.ndarray  # [V] int32 entries received
    steal_depth: jnp.ndarray  # [V] int32 summed depth of stolen entries
    steal_rounds: jnp.ndarray  # [] int32 rounds with any transfer
    steps: jnp.ndarray  # [] int32
    overflow: jnp.ndarray  # [] bool — stack high-watermark breached
    match_buf: jnp.ndarray  # [V, Mcap, P] int32 (Mcap >= 1)


class Popped(NamedTuple):
    """Top-of-stack lanes selected by :func:`pop_top_k`.

    Off lanes (``lane_on == False``) carry zeroed depth/candidates so the
    expansion backend never has to re-check the lane mask for validity.
    """

    depth: jnp.ndarray  # [V, E] int32 (0 on off lanes)
    map: jnp.ndarray  # [V, E, P] int32
    used: jnp.ndarray  # [V, E, W] uint32 (materialized even w/o store_used)
    cand: jnp.ndarray  # [V, E, W] uint32 (0 on off lanes)
    lane_on: jnp.ndarray  # [V, E] bool
    k: jnp.ndarray  # [V] int32 entries actually popped per worker


def used_from_map(map_: jnp.ndarray, depth: jnp.ndarray, w: int) -> jnp.ndarray:
    """Reconstruct one entry's used-bitmap from mapped targets at positions
    < depth (the ``store_used=False`` stack representation)."""
    p_pad = map_.shape[0]

    def body(j, u):
        valid = (j < depth) & (map_[j] >= 0)
        t = jnp.maximum(map_[j], 0)
        word = t // WORD_BITS
        bit = jnp.where(valid, jnp.uint32(1) << (t % WORD_BITS).astype(jnp.uint32),
                        jnp.uint32(0))
        return u.at[word].set(u[word] | bit)

    return lax.fori_loop(0, p_pad, body, jnp.zeros((w,), jnp.uint32))


def pop_top_k(
    st_depth: jnp.ndarray,
    st_map: jnp.ndarray,
    st_used: jnp.ndarray,
    st_cand: jnp.ndarray,
    base: jnp.ndarray,
    size: jnp.ndarray,
    expand_width: int,
    store_used: bool = True,
) -> Popped:
    """Select each worker's top ``expand_width`` entries (top-first lanes).

    ``k = min(size, expand_width, free_space)`` per worker — the capacity
    guard: a worker never pops more than it could push back (each popped
    entry re-emits at most a parent + a child, net growth ≤ k), so a full
    ring (``free_space == 0``) freezes rather than corrupts.  Popping is
    logical only — ``size`` is adjusted by the subsequent
    :func:`push_entries`, which reuses the vacated slots.
    """
    v_loc, s_cap = st_depth.shape
    w = st_cand.shape[2]
    e = expand_width

    space = s_cap - size
    k = jnp.minimum(jnp.minimum(size, e), space).astype(jnp.int32)
    lane = jnp.arange(e, dtype=jnp.int32)[None, :]
    lane_on = lane < k[:, None]
    pos = size[:, None] - 1 - lane  # top-first
    slot = jnp.where(lane_on, (base[:, None] + pos) % s_cap, 0)
    vidx = jnp.arange(v_loc, dtype=jnp.int32)[:, None]

    depth = jnp.where(lane_on, st_depth[vidx, slot], 0)
    cand = jnp.where(lane_on[..., None], st_cand[vidx, slot], jnp.uint32(0))
    map_ = st_map[vidx, slot]
    if store_used:
        used = st_used[vidx, slot]
    else:
        used = jax.vmap(jax.vmap(lambda m, d: used_from_map(m, d, w)))(map_, depth)
    return Popped(depth, map_, used, cand, lane_on, k)


def push_entries(
    st_depth: jnp.ndarray,
    st_map: jnp.ndarray,
    st_used: jnp.ndarray,
    st_cand: jnp.ndarray,
    base: jnp.ndarray,
    size: jnp.ndarray,
    k: jnp.ndarray,
    parent_keep: jnp.ndarray,  # [V, E] parents with remaining candidates
    has_child: jnp.ndarray,  # [V, E] lanes that emitted a live child
    p_depth: jnp.ndarray,  # parent re-push payload ([V, E] / [V, E, ...])
    p_map: jnp.ndarray,
    p_used: jnp.ndarray,
    p_cand: jnp.ndarray,
    c_depth: jnp.ndarray,  # child payload
    c_map: jnp.ndarray,
    c_used: jnp.ndarray,
    c_cand: jnp.ndarray,
    store_used: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Push surviving parents below their fresh children, lanes k-1 .. 0.

    Emission is reversed-lane (lane k-1 first) so lane 0 — the deepest,
    top-of-stack entry — ends back on top: per-worker DFS order is
    preserved across steps.  Slots are assigned by a per-worker prefix sum
    over ``(parent_keep, has_child)``; invalid lanes address slot
    ``s_cap`` and are dropped by the scatter.  Returns the updated stack
    arrays and the new ``size``.
    """
    v_loc, s_cap = st_depth.shape
    e = parent_keep.shape[1]
    lane = jnp.arange(e, dtype=jnp.int32)
    rev = e - 1 - lane  # reversal is its own inverse
    pk_r = parent_keep[:, rev]
    hc_r = has_child[:, rev]
    per_lane = pk_r.astype(jnp.int32) + hc_r.astype(jnp.int32)
    offs = jnp.cumsum(per_lane, axis=1) - per_lane  # first push of lane rev[i]
    parent_out = jnp.where(pk_r, offs, -1)[:, rev]
    child_out = jnp.where(hc_r, offs + pk_r.astype(jnp.int32), -1)[:, rev]
    total_push = jnp.sum(per_lane, axis=1)

    new_size = size - k + total_push
    push_base = size - k  # logical position of first pushed entry

    def slots_for(out_pos):
        slot = (base[:, None] + push_base[:, None] + out_pos) % s_cap
        return jnp.where(out_pos >= 0, slot, s_cap)

    p_slots = slots_for(parent_out)
    c_slots = slots_for(child_out)
    vidx = jnp.arange(v_loc, dtype=jnp.int32)[:, None]

    st_depth = st_depth.at[vidx, p_slots].set(p_depth, mode="drop")
    st_map = st_map.at[vidx, p_slots].set(p_map, mode="drop")
    st_cand = st_cand.at[vidx, p_slots].set(p_cand, mode="drop")

    st_depth = st_depth.at[vidx, c_slots].set(c_depth, mode="drop")
    st_map = st_map.at[vidx, c_slots].set(c_map, mode="drop")
    st_cand = st_cand.at[vidx, c_slots].set(c_cand, mode="drop")

    if store_used:
        st_used = st_used.at[vidx, p_slots].set(p_used, mode="drop")
        st_used = st_used.at[vidx, c_slots].set(c_used, mode="drop")

    return st_depth, st_map, st_used, st_cand, new_size


def compact(
    st_depth: jnp.ndarray,
    st_map: jnp.ndarray,
    st_used: jnp.ndarray,
    st_cand: jnp.ndarray,
    base: jnp.ndarray,
    size: jnp.ndarray,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Rotate every ring so its logical bottom lands in slot 0 (base → 0).

    Entry order and contents are unchanged — only the physical layout.
    Steal rounds don't need this (they address slots modulo ``s_cap``),
    but backends that want contiguous stack segments (the sparse-CSR
    direction in ROADMAP.md) and state re-initialization do.
    """
    v_loc, s_cap = st_depth.shape
    j = jnp.arange(s_cap, dtype=jnp.int32)[None, :]
    slot = (base[:, None] + j) % s_cap
    vidx = jnp.arange(v_loc, dtype=jnp.int32)[:, None]
    return (
        st_depth[vidx, slot],
        st_map[vidx, slot],
        st_used[vidx, slot],
        st_cand[vidx, slot],
        jnp.zeros_like(base),
        size,
    )


def overflowed(size: jnp.ndarray, s_cap: int) -> jnp.ndarray:
    """High-watermark check: a completely full ring (``size == s_cap``)
    counts as overflow — the pop guard then freezes the worker, silently
    undercounting, which is why the session retries with a doubled cap
    (`repro.core.session.Enumerator.run`)."""
    return jnp.any(size > s_cap - 1)


# ---------------------------------------------------------------------------
# state construction / sharding metadata
# ---------------------------------------------------------------------------

def init_state(plan: SearchPlan, cfg: "EngineConfig") -> EngineState:
    """Initial work distribution, dispatched on ``cfg.root_seeding``
    (DESIGN.md §10).

    ``"vertex"`` is the paper's §3.3 scheme — depth-0 candidates split into
    equal contiguous target-node ranges, one root entry per worker.
    ``"edge"`` enumerates the plan's seed edge class into depth-1 entries
    (:func:`root_seed_entries`) dealt round-robin across workers — the
    HiPerMotif-style injection that shrinks hub-heavy root frontiers by
    orders of magnitude; when the class is too populous for the stacks, it
    falls back to a depth-0 split restricted to the qualifying source
    nodes (a sound pruning — deterministic per ``(plan, cfg)``, so
    counters agree across step backends).  ``"auto"`` is ``"edge"`` iff
    the plan carries a seed edge.  Every execution path — ``engine.run``,
    ``run_sharded``, and the session — seeds through this one function,
    and the match set is identical in all modes.
    """
    mode = cfg.root_seeding
    if mode == "auto":
        mode = "edge" if plan.seed_edge is not None else "vertex"
    if mode == "edge":
        if plan.seed_edge is None:
            raise ValueError(
                "root_seeding='edge' requires a plan built with seed_edge= "
                "(plan.seed_edge is unset; see repro.core.plan.build_plan)"
            )
        sd, sm, sc = root_seed_entries(plan)
        v = cfg.n_workers
        s_cap = cfg.resolved_stack_cap(plan.p_pad)
        k = int(sd.shape[0])
        per_worker = -(-k // v) if k else 0
        if per_worker <= s_cap - 1:
            return init_delta_state(plan, cfg, sd, sm, sc)
        mask = bitmap_from_indices(
            sm[:, 0].astype(np.int64), plan.n_t, plan.w
        )
        return _init_vertex_state(plan, cfg, root_mask=mask)
    return _init_vertex_state(plan, cfg)


def root_seed_entries(plan: SearchPlan):
    """Depth-1 engine seeds for edge-centric root seeding (DESIGN.md §10).

    The seed edge's endpoints hold ordering positions 0/1, so each target
    arc of the seed class becomes one partial embedding: map position 0 to
    the arc's source ``t`` and store position 1's candidate bitmap
    (`repro.core.extend.host_cand_bitmap` — engine-valid, candidates are
    trusted downstream, exactly the PR-7 delta-seed contract).  Sources are
    drawn from ``dom[0]`` restricted to rows with a non-empty segment in
    the seed constraint's plane, so the work is proportional to the *rare
    class*, not the target.  Returns ``(seed_depth [K], seed_map [K,
    p_pad], seed_cand [K, w])`` sorted by source node — deterministic and
    backend-independent, which is what keeps per-backend counters identical
    under edge seeding.
    """
    from repro.core.extend import _plan_csr, host_cand_bitmap

    p_pad, w = plan.p_pad, plan.w
    empty = (
        np.zeros((0,), np.int32),
        np.zeros((0, p_pad), np.int32),
        np.zeros((0, w), np.uint32),
    )
    if not plan.satisfiable or plan.n_p < 2:
        return empty

    from repro.core.graph import bitmap_to_indices

    dom0_idx = bitmap_to_indices(plan.dom_bits[0])
    # the position-1 parent slot referencing position 0 IS the seed edge
    j0 = next(
        (j for j in range(plan.max_parents) if int(plan.parent_pos[1, j]) == 0),
        None,
    )
    if j0 is not None:
        plane = int(plan.parent_elab[1, j0]) * 2 + int(plan.parent_dir[1, j0])
        ptr = _plan_csr(plan).indptr[plane].astype(np.int64)
        lens = ptr[dom0_idx + 1] - ptr[dom0_idx]
        dom0_idx = dom0_idx[lens > 0]
    seeds_m, seeds_c = [], []
    m = np.full(p_pad, -1, dtype=np.int32)
    for t in dom0_idx.tolist():
        m[0] = t
        c1 = host_cand_bitmap(plan, 1, m)
        if c1.any():
            seeds_m.append(m.copy())
            seeds_c.append(c1)
    if not seeds_m:
        return empty
    return (
        np.ones(len(seeds_m), dtype=np.int32),
        np.stack(seeds_m).astype(np.int32),
        np.stack(seeds_c).astype(np.uint32),
    )


def _init_vertex_state(
    plan: SearchPlan, cfg: "EngineConfig", root_mask: Optional[np.ndarray] = None
) -> EngineState:
    """The classic depth-0 root split; ``root_mask`` optionally restricts
    the root candidates (edge seeding's capacity fallback)."""
    v = cfg.n_workers
    p_pad, w = plan.p_pad, plan.w
    s_cap = cfg.resolved_stack_cap(p_pad)
    mcap = max(1, cfg.collect_matches)

    splits = np.linspace(0, plan.n_t, v + 1).astype(np.int64)
    root_cands = np.zeros((v, w), dtype=np.uint32)
    for kk in range(v):
        idx = np.arange(splits[kk], splits[kk + 1])
        if idx.size:
            root_cands[kk] = bitmap_from_indices(idx, plan.n_t, w) & plan.dom_bits[0]
    if root_mask is not None:
        root_cands &= root_mask[None, :]
    if not plan.satisfiable:
        root_cands[:] = 0

    st_depth = np.zeros((v, s_cap), dtype=np.int32)
    st_map = np.full((v, s_cap, p_pad), -1, dtype=np.int32)
    st_used = np.zeros((v, s_cap, w if cfg.store_used else 1), dtype=np.uint32)
    st_cand = np.zeros((v, s_cap, w), dtype=np.uint32)
    st_cand[:, 0] = root_cands
    size = (root_cands.any(axis=1)).astype(np.int32)

    return EngineState(
        st_depth=jnp.asarray(st_depth),
        st_map=jnp.asarray(st_map),
        st_used=jnp.asarray(st_used),
        st_cand=jnp.asarray(st_cand),
        base=jnp.zeros((v,), jnp.int32),
        size=jnp.asarray(size),
        matches=jnp.zeros((v,), jnp.int32),
        states=jnp.zeros((v,), jnp.int32),
        exp_depth=jnp.zeros((v,), jnp.int32),
        steals=jnp.zeros((v,), jnp.int32),
        steal_depth=jnp.zeros((v,), jnp.int32),
        steal_rounds=jnp.zeros((), jnp.int32),
        steps=jnp.zeros((), jnp.int32),
        overflow=jnp.zeros((), jnp.bool_),
        match_buf=jnp.full((v, mcap, p_pad), -1, jnp.int32),
    )


def init_delta_state(
    plan: SearchPlan,
    cfg: "EngineConfig",
    seed_depth: np.ndarray,
    seed_map: np.ndarray,
    seed_cand: np.ndarray,
) -> EngineState:
    """Seeded :class:`EngineState` for delta enumeration (DESIGN.md §8).

    Instead of :func:`init_state`'s depth-0 root split, worker stacks start
    from the given partial-embedding entries — one per inserted target edge
    anchored onto a pattern edge.  ``seed_depth [K]`` / ``seed_map [K,
    p_pad]`` / ``seed_cand [K, w]`` must already be engine-valid
    (`repro.core.extend.host_cand_bitmap` semantics: candidate bits are
    trusted, never re-checked).  Seeds are dealt round-robin across the
    ``V`` workers; the caller chunks ``K`` so no worker exceeds the stack
    capacity.
    """
    v = cfg.n_workers
    p_pad, w = plan.p_pad, plan.w
    s_cap = cfg.resolved_stack_cap(p_pad)
    mcap = max(1, cfg.collect_matches)

    seed_depth = np.asarray(seed_depth, dtype=np.int32)
    seed_map = np.asarray(seed_map, dtype=np.int32)
    seed_cand = np.asarray(seed_cand, dtype=np.uint32)
    k = int(seed_depth.shape[0])
    per_worker = -(-k // v) if k else 0
    if per_worker > s_cap - 1:
        raise ValueError(
            f"{k} delta seeds over {v} workers exceed stack_cap={s_cap}; "
            "chunk the seed batch"
        )

    st_depth = np.zeros((v, s_cap), dtype=np.int32)
    st_map = np.full((v, s_cap, p_pad), -1, dtype=np.int32)
    st_used = np.zeros((v, s_cap, w if cfg.store_used else 1), dtype=np.uint32)
    st_cand = np.zeros((v, s_cap, w), dtype=np.uint32)
    size = np.zeros((v,), dtype=np.int32)
    for i in range(k):
        wk = i % v
        slot = size[wk]
        st_depth[wk, slot] = seed_depth[i]
        st_map[wk, slot] = seed_map[i]
        st_cand[wk, slot] = seed_cand[i]
        if cfg.store_used:
            prefix = seed_map[i, : seed_depth[i]].astype(np.int64)
            st_used[wk, slot] = bitmap_from_indices(
                prefix[prefix >= 0], plan.n_t, w
            )
        size[wk] = slot + 1

    return EngineState(
        st_depth=jnp.asarray(st_depth),
        st_map=jnp.asarray(st_map),
        st_used=jnp.asarray(st_used),
        st_cand=jnp.asarray(st_cand),
        base=jnp.zeros((v,), jnp.int32),
        size=jnp.asarray(size),
        matches=jnp.zeros((v,), jnp.int32),
        states=jnp.zeros((v,), jnp.int32),
        exp_depth=jnp.zeros((v,), jnp.int32),
        steals=jnp.zeros((v,), jnp.int32),
        steal_depth=jnp.zeros((v,), jnp.int32),
        steal_rounds=jnp.zeros((), jnp.int32),
        steps=jnp.zeros((), jnp.int32),
        overflow=jnp.zeros((), jnp.bool_),
        match_buf=jnp.full((v, mcap, p_pad), -1, jnp.int32),
    )


# ---------------------------------------------------------------------------
# spill frontier (out-of-core partitioned enumeration, DESIGN.md §9)
# ---------------------------------------------------------------------------

class SpillState(NamedTuple):
    """Per-worker ring of entries parked for a non-resident partition.

    A spill entry is a child whose candidate bitmap is only *partially*
    constrained: ``sp_pending`` bit ``j`` set means parent slot ``j``'s
    adjacency row lives outside the resident partition and has not been
    intersected yet.  ``sp_part`` is the owning partition of the first
    pending parent — the host drains rings at quiescence and routes entries
    into per-partition pools.  The used-bitmap is not stored; intake
    reconstructs it from the mapping prefix (``store_used=False``
    representation).  Same overflow-watermark semantics as the live stack:
    ``sp_overflow`` latches when a push would exceed capacity, and the
    driver treats a near-full ring as a yield point (drain, then resume).
    """

    sp_depth: jnp.ndarray  # [V, C] int32
    sp_map: jnp.ndarray  # [V, C, P] int32
    sp_cand: jnp.ndarray  # [V, C, W] uint32 partially-constrained candidates
    sp_pending: jnp.ndarray  # [V, C] int32 bitmask of unapplied parent slots
    sp_part: jnp.ndarray  # [V, C] int32 partition owning first pending parent
    sp_size: jnp.ndarray  # [V] int32
    sp_overflow: jnp.ndarray  # [] bool — ring watermark breached


def init_spill_state(v: int, spill_cap: int, p_pad: int, w: int) -> SpillState:
    return SpillState(
        sp_depth=jnp.zeros((v, spill_cap), jnp.int32),
        sp_map=jnp.full((v, spill_cap, p_pad), -1, jnp.int32),
        sp_cand=jnp.zeros((v, spill_cap, w), jnp.uint32),
        sp_pending=jnp.zeros((v, spill_cap), jnp.int32),
        sp_part=jnp.full((v, spill_cap), -1, jnp.int32),
        sp_size=jnp.zeros((v,), jnp.int32),
        sp_overflow=jnp.zeros((), jnp.bool_),
    )


def push_spill(
    spill: SpillState,
    flags: jnp.ndarray,  # [V, E] lanes that produced a spill entry
    e_depth: jnp.ndarray,  # [V, E] int32
    e_map: jnp.ndarray,  # [V, E, P] int32
    e_cand: jnp.ndarray,  # [V, E, W] uint32
    e_pending: jnp.ndarray,  # [V, E] int32
    e_part: jnp.ndarray,  # [V, E] int32
) -> SpillState:
    """Append flagged lanes to each worker's spill ring (worker-local, no
    cross-device traffic).  Slots are assigned by per-worker prefix sum;
    pushes past capacity are dropped and latch ``sp_overflow`` — the driver
    yields to the host for a drain well before that (watermark), so the
    latch only fires if a single round overshoots the drain margin.
    """
    v_loc, c_cap = spill.sp_depth.shape
    fl = flags.astype(jnp.int32)
    offs = jnp.cumsum(fl, axis=1) - fl
    slot = jnp.where(flags, spill.sp_size[:, None] + offs, c_cap)
    slot_c = jnp.where(slot < c_cap, slot, c_cap)
    vidx = jnp.arange(v_loc, dtype=jnp.int32)[:, None]
    new_size = spill.sp_size + jnp.sum(fl, axis=1)
    return SpillState(
        sp_depth=spill.sp_depth.at[vidx, slot_c].set(e_depth, mode="drop"),
        sp_map=spill.sp_map.at[vidx, slot_c].set(e_map, mode="drop"),
        sp_cand=spill.sp_cand.at[vidx, slot_c].set(e_cand, mode="drop"),
        sp_pending=spill.sp_pending.at[vidx, slot_c].set(e_pending, mode="drop"),
        sp_part=spill.sp_part.at[vidx, slot_c].set(e_part, mode="drop"),
        sp_size=jnp.minimum(new_size, c_cap).astype(jnp.int32),
        sp_overflow=spill.sp_overflow | jnp.any(new_size > c_cap),
    )


def spill_watermark(spill: SpillState, margin: int) -> jnp.ndarray:
    """True when any worker's ring is within ``margin`` pushes of capacity —
    the driver's cue to return control to the host for a drain."""
    c_cap = spill.sp_depth.shape[1]
    return jnp.any(spill.sp_size >= c_cap - margin)


def spill_partition_specs(axis: str) -> SpillState:
    """PartitionSpecs for :class:`SpillState` under the mesh ``data`` axis."""
    P = PartitionSpec
    return SpillState(
        sp_depth=P(axis, None),
        sp_map=P(axis, None, None),
        sp_cand=P(axis, None, None),
        sp_pending=P(axis, None),
        sp_part=P(axis, None),
        sp_size=P(axis),
        sp_overflow=P(),
    )


def state_partition_specs(axis: str) -> EngineState:
    """PartitionSpecs for :class:`EngineState`: worker-axis arrays sharded
    over ``axis``, loop scalars replicated."""
    P = PartitionSpec
    return EngineState(
        st_depth=P(axis, None),
        st_map=P(axis, None, None),
        st_used=P(axis, None, None),
        st_cand=P(axis, None, None),
        base=P(axis),
        size=P(axis),
        matches=P(axis),
        states=P(axis),
        exp_depth=P(axis),
        steals=P(axis),
        steal_depth=P(axis),
        steal_rounds=P(),
        steps=P(),
        overflow=P(),
        match_buf=P(axis, None, None),
    )


def abstract_engine_state(cfg: "EngineConfig", w: int, p_pad: int) -> EngineState:
    """ShapeDtypeStructs for dry-run lowering without allocation."""
    v = cfg.n_workers
    s_cap = cfg.resolved_stack_cap(p_pad)
    mcap = max(1, cfg.collect_matches)
    w_used = w if cfg.store_used else 1
    sds = jax.ShapeDtypeStruct
    return EngineState(
        st_depth=sds((v, s_cap), jnp.int32),
        st_map=sds((v, s_cap, p_pad), jnp.int32),
        st_used=sds((v, s_cap, w_used), jnp.uint32),
        st_cand=sds((v, s_cap, w), jnp.uint32),
        base=sds((v,), jnp.int32),
        size=sds((v,), jnp.int32),
        matches=sds((v,), jnp.int32),
        states=sds((v,), jnp.int32),
        exp_depth=sds((v,), jnp.int32),
        steals=sds((v,), jnp.int32),
        steal_depth=sds((v,), jnp.int32),
        steal_rounds=sds((), jnp.int32),
        steps=sds((), jnp.int32),
        overflow=sds((), jnp.bool_),
        match_buf=sds((v, mcap, p_pad), jnp.int32),
    )


STATE_LOGICAL = EngineState(
    st_depth=("worker", None),
    st_map=("worker", None, None),
    st_used=("worker", None, "tensor"),
    st_cand=("worker", None, "tensor"),
    base=("worker",),
    size=("worker",),
    matches=("worker",),
    states=("worker",),
    exp_depth=("worker",),
    steals=("worker",),
    steal_depth=("worker",),
    steal_rounds=(),
    steps=(),
    overflow=(),
    match_buf=("worker", None, None),
)
