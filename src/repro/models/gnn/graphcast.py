"""GraphCast-style encoder–processor–decoder mesh GNN.

Three bipartite/recurrent edge sets (grid→mesh encoder, ``n_layers`` of mesh
message passing, mesh→grid decoder), each an interaction-network step:

  e'  = MLP([h_src, h_dst, e]) + e        (edge update)
  h'  = MLP([h, Σ_{e into v} e']) + h     (node update, sum aggregation)

For the weather configuration the mesh is an icosahedral refinement
(refinement 6 ⇒ 40,962 mesh nodes) and grid nodes carry ``n_vars = 227``
channels; `repro.data.graphgen.icosa_mesh_shape` provides the synthetic
topology.  For the generic GNN benchmark shapes the same architecture runs
with the target graph as "grid", a subsampled node set as "mesh", and
fanout-4 bipartite edges (DESIGN.md §4) — the compute pattern (three edge
sets, deep mesh processor) is preserved across every cell.

Processor layers are scanned (stacked params) so the 16-layer processor
lowers to one compiled block.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed.shardings import constraint
from repro.models.common import ParamSpec, dot
from repro.models.gnn.common import gather_src, masked_softmax_ce, segment_sum


@dataclasses.dataclass(frozen=True)
class GraphCastConfig:
    n_layers: int = 16
    d_hidden: int = 512
    mesh_refinement: int = 6
    aggregator: str = "sum"
    n_vars: int = 227
    d_edge_in: int = 4  # static edge features (displacement etc.)


def _mlp2(prefix: str, d_in: int, d: int, d_out: int) -> Dict[str, ParamSpec]:
    return {
        f"{prefix}_w0": ParamSpec((d_in, d), (None, "tensor"), jnp.float32),
        f"{prefix}_b0": ParamSpec((d,), (None,), jnp.float32, init="zeros"),
        f"{prefix}_w1": ParamSpec((d, d_out), ("tensor", None), jnp.float32),
        f"{prefix}_b1": ParamSpec((d_out,), (None,), jnp.float32, init="zeros"),
    }


def _mlp2_stack(prefix: str, l: int, d_in: int, d: int, d_out: int) -> Dict[str, ParamSpec]:
    return {
        f"{prefix}_w0": ParamSpec((l, d_in, d), (None, None, "tensor"), jnp.float32),
        f"{prefix}_b0": ParamSpec((l, d), (None, None), jnp.float32, init="zeros"),
        f"{prefix}_w1": ParamSpec((l, d, d_out), (None, "tensor", None), jnp.float32),
        f"{prefix}_b1": ParamSpec((l, d_out), (None, None), jnp.float32, init="zeros"),
    }


def param_specs(cfg: GraphCastConfig, d_in: int, d_out: int) -> Dict[str, ParamSpec]:
    d = cfg.d_hidden
    specs: Dict[str, ParamSpec] = {}
    specs.update(_mlp2("embed_grid", d_in, d, d))
    specs.update(_mlp2("embed_mesh", cfg.d_edge_in, d, d))  # mesh feats = coords
    specs.update(_mlp2("embed_e_g2m", cfg.d_edge_in, d, d))
    specs.update(_mlp2("embed_e_mesh", cfg.d_edge_in, d, d))
    specs.update(_mlp2("embed_e_m2g", cfg.d_edge_in, d, d))
    specs.update(_mlp2("g2m_edge", 3 * d, d, d))
    specs.update(_mlp2("g2m_mesh", 2 * d, d, d))
    specs.update(_mlp2_stack("proc_edge", cfg.n_layers, 3 * d, d, d))
    specs.update(_mlp2_stack("proc_node", cfg.n_layers, 2 * d, d, d))
    specs.update(_mlp2("m2g_edge", 3 * d, d, d))
    specs.update(_mlp2("m2g_grid", 2 * d, d, d))
    specs.update(_mlp2("decode", d, d, d_out))
    return specs


def _mlp(p, prefix: str, x: jnp.ndarray) -> jnp.ndarray:
    h = jax.nn.silu(dot(x, p[f"{prefix}_w0"]) + p[f"{prefix}_b0"])
    return dot(h, p[f"{prefix}_w1"]) + p[f"{prefix}_b1"]


def _interact(p, prefix_e: str, prefix_n: str, h_src, h_dst, e, src, dst):
    """One interaction-network step over a (bipartite) edge set."""
    msg_in = jnp.concatenate(
        [gather_src(h_src, src), gather_src(h_dst, dst), e], axis=-1
    )
    e2 = _mlp(p, prefix_e, msg_in) + e
    agg = segment_sum(e2, dst, h_dst.shape[0])
    h2 = _mlp(p, prefix_n, jnp.concatenate([h_dst, agg], axis=-1)) + h_dst
    return h2, e2


def forward(params, cfg: GraphCastConfig, batch: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    p = params
    hg = _mlp(p, "embed_grid", batch["feats"])  # grid nodes
    hm = _mlp(p, "embed_mesh", batch["mesh_feats"])  # mesh nodes
    e_g2m = _mlp(p, "embed_e_g2m", batch["g2m_efeats"])
    e_mesh = _mlp(p, "embed_e_mesh", batch["mesh_efeats"])
    e_m2g = _mlp(p, "embed_e_m2g", batch["m2g_efeats"])

    # --- encoder: grid -> mesh ---------------------------------------------
    hm, _ = _interact(p, "g2m_edge", "g2m_mesh", hg, hm, e_g2m,
                      batch["g2m_src"], batch["g2m_dst"])

    # --- processor: n_layers on the mesh graph (scanned) --------------------
    stack_keys = ["proc_edge_w0", "proc_edge_b0", "proc_edge_w1", "proc_edge_b1",
                  "proc_node_w0", "proc_node_b0", "proc_node_w1", "proc_node_b1"]
    stacked = {k: p[k] for k in stack_keys}
    msrc, mdst = batch["mesh_src"], batch["mesh_dst"]

    def layer(carry, lp):
        hm, e = carry
        hm2, e2 = _interact(lp, "proc_edge", "proc_node", hm, hm, e, msrc, mdst)
        return (hm2, e2), None

    step = jax.checkpoint(layer, policy=jax.checkpoint_policies.nothing_saveable)
    (hm, e_mesh), _ = lax.scan(step, (hm, e_mesh), stacked)

    # --- decoder: mesh -> grid ----------------------------------------------
    hg, _ = _interact(p, "m2g_edge", "m2g_grid", hm, hg, e_m2g,
                      batch["m2g_src"], batch["m2g_dst"])
    return _mlp(p, "decode", hg)


def loss_fn(params, cfg: GraphCastConfig, batch):
    out = forward(params, cfg, batch)
    if "labels" in batch:
        loss, count = masked_softmax_ce(out, batch["labels"])
        return loss, {"loss": loss, "nodes": count}
    loss = jnp.mean(jnp.square(out - batch["targets"]))
    return loss, {"loss": loss}
