"""§Perf — before/after comparison between dry-run artifact directories.

  PYTHONPATH=src python -m benchmarks.perf_compare [baseline_dir] [current_dir]

Prints a per-cell markdown table of the three roofline terms before and
after the optimization iterations, with the dominant-term delta highlighted.
"""

from __future__ import annotations

import glob
import json
import os
import sys

from benchmarks import roofline

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")


def load(d: str):
    out = {}
    for path in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("skipped"):
            continue
        out[rec["cell"]] = roofline.terms(rec)
    return out


def compare(base_dir: str, cur_dir: str, mesh: str = "single") -> str:
    base = load(os.path.join(base_dir, mesh))
    cur = load(os.path.join(cur_dir, mesh))
    rows = []
    for cell in sorted(set(base) | set(cur)):
        b, c = base.get(cell), cur.get(cell)
        if not b or not c:
            continue
        dom = b["dominant"]
        key = f"t_{dom}" if dom != "collective" else "t_collective"
        before = b[key]
        after = c[key]
        speed = before / max(after, 1e-30)
        rows.append(
            f"| {cell} | {dom} | {before:.3e} | {after:.3e} | {speed:7.2f}× "
            f"| {b['roofline_fraction']:.4f} | {c['roofline_fraction']:.4f} |"
        )
    hdr = ("| cell | dominant(before) | term before (s) | term after (s) | Δ "
           "| frac before | frac after |\n|---|---|---|---|---|---|---|")
    return hdr + "\n" + "\n".join(rows)


def main() -> None:
    base_dir = sys.argv[1] if len(sys.argv) > 1 else os.path.join(ART, "dryrun_baseline")
    cur_dir = sys.argv[2] if len(sys.argv) > 2 else os.path.join(ART, "dryrun")
    for mesh in ("single", "multi"):
        if os.path.isdir(os.path.join(base_dir, mesh)) and os.path.isdir(
            os.path.join(cur_dir, mesh)
        ):
            print(f"\n## {mesh} mesh\n")
            print(compare(base_dir, cur_dir, mesh))


if __name__ == "__main__":
    main()
