"""GCN (Kipf & Welling) with symmetric normalization.

``H' = act( Â H W )`` with ``Â = D^{-1/2}(A + I)D^{-1/2}`` realized as
edge-gather → per-edge norm weight → segment-sum + normalized self term.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.distributed.shardings import constraint
from repro.models.common import ParamSpec, dot
from repro.models.gnn.common import gather_src, masked_softmax_ce, segment_sum, sym_norm_weights


@dataclasses.dataclass(frozen=True)
class GCNConfig:
    n_layers: int = 2
    d_hidden: int = 16
    aggregator: str = "mean"
    norm: str = "sym"
    dropout: float = 0.0  # inference-style determinism for benchmarks


def param_specs(cfg: GCNConfig, d_in: int, d_out: int) -> Dict[str, ParamSpec]:
    dims = [d_in] + [cfg.d_hidden] * (cfg.n_layers - 1) + [d_out]
    specs: Dict[str, ParamSpec] = {}
    for i in range(cfg.n_layers):
        specs[f"w{i}"] = ParamSpec(
            (dims[i], dims[i + 1]), (None, "tensor" if i == 0 else None), jnp.float32
        )
        specs[f"b{i}"] = ParamSpec((dims[i + 1],), (None,), jnp.float32, init="zeros")
    return specs


def forward(params, cfg: GCNConfig, batch: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    h = batch["feats"]
    src, dst = batch["src"], batch["dst"]
    n = h.shape[0]
    ew = sym_norm_weights(src, dst, n)  # [E]
    ones = jnp.ones((src.shape[0],), jnp.float32)
    deg = jax.ops.segment_sum(ones, dst, num_segments=n) + 1.0
    self_w = (1.0 / deg)[:, None]
    for i in range(cfg.n_layers):
        hw = dot(h, params[f"w{i}"])
        msg = gather_src(hw, src) * ew[:, None]
        agg = segment_sum(msg, dst, n) + hw * self_w
        h = agg + params[f"b{i}"]
        if i < cfg.n_layers - 1:
            h = jax.nn.relu(h)
        h = constraint(h, (None, None))
    return h


def loss_fn(params, cfg: GCNConfig, batch):
    logits = forward(params, cfg, batch)
    loss, count = masked_softmax_ce(logits, batch["labels"])
    return loss, {"loss": loss, "nodes": count}
