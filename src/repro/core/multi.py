"""Multi-query enumeration: a batch of pattern queries against one target.

The paper's workloads are collections of *thousands* of patterns per target
(PPIS32: 420, PDBSv1: 1760).  This driver packs queries with padded-common
plan shapes and runs the engine **vmapped over the query axis** — on the
production mesh that axis maps to ``pod`` (DESIGN.md §5), so independent
queries occupy independent pods while each query still uses its pod's
worker/tensor parallelism.

The vmapped ``while_loop`` runs until *all* queries in a pack drain; packs
are therefore built by LPT-balancing predicted work (`balance_assignment` —
the paper's scheduling insight applied one level up).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine as eng
from repro.core.engine import EngineConfig
from repro.core.graph import Graph, PackedGraph, popcount
from repro.core.plan import SearchPlan, build_plan
from repro.core.scheduler import balance_assignment


@dataclasses.dataclass
class QueryResult:
    name: str
    matches: int
    states: int
    steps: int


def _stack_plans(plans: Sequence[SearchPlan]) -> eng.PlanArrays:
    arrays = [eng.make_plan_arrays(p) for p in plans]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *arrays)


def run_batch(plans: Sequence[SearchPlan], cfg: EngineConfig):
    """Run a pack of same-shaped plans; returns stacked final EngineStates."""
    stacked = _stack_plans(plans)
    states = jax.tree.map(
        lambda *xs: jnp.stack(xs), *[eng.init_state(p, cfg) for p in plans]
    )

    @jax.jit
    def go(plan_arrays, st):
        return jax.vmap(lambda pl, s: eng._engine_loop(cfg, pl, s))(plan_arrays, st)

    return jax.block_until_ready(go(stacked, states))


def enumerate_many(
    patterns: Sequence[Graph],
    target: Graph,
    variant: str = "ri-ds-si-fc",
    cfg: Optional[EngineConfig] = None,
    pack_size: int = 4,
    names: Optional[Sequence[str]] = None,
) -> List[QueryResult]:
    """Enumerate every pattern against ``target`` in LPT-balanced packs."""
    cfg = cfg or EngineConfig(n_workers=8, expand_width=4)
    packed = PackedGraph.from_graph(target)
    p_pad = max(16, max((((p.n + 15) // 16) * 16) for p in patterns))
    mp = 8
    plans = [
        build_plan(p, packed, variant=variant, p_pad=p_pad, max_parents=mp)
        for p in patterns
    ]
    names = list(names or [f"q{i}" for i in range(len(patterns))])

    # predicted work ~ product of the first few domain sizes (cheap proxy)
    def predict(plan: SearchPlan) -> float:
        sizes = popcount(plan.dom_bits[: min(plan.n_p, 4)])
        return float(np.prod(np.maximum(sizes, 1), dtype=np.float64))

    n_packs = max(1, (len(plans) + pack_size - 1) // pack_size)
    assignment = balance_assignment([predict(p) for p in plans], n_packs)

    out: List[Optional[QueryResult]] = [None] * len(plans)
    for pack_id in range(n_packs):
        idx = [i for i, a in enumerate(assignment) if a == pack_id]
        if not idx:
            continue
        runnable = [i for i in idx if plans[i].satisfiable]
        for i in idx:
            if not plans[i].satisfiable:
                out[i] = QueryResult(names[i], 0, 0, 0)
        if not runnable:
            continue
        finals = run_batch([plans[i] for i in runnable], cfg)
        for row, i in enumerate(runnable):
            one = jax.tree.map(lambda x: x[row], finals)
            if bool(one.overflow):
                raise RuntimeError(f"stack overflow in query {names[i]}")
            out[i] = QueryResult(
                name=names[i],
                matches=int(jnp.sum(one.matches)),
                states=int(jnp.sum(one.states)),
                steps=int(one.steps),
            )
    return [r for r in out if r is not None]
