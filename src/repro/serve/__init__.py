"""Always-on enumeration serving layer (DESIGN.md §7).

Admission → coalescing → execution over the `repro.core.session` API:

  service    — EnumerationService: the long-lived server + dispatcher
  admission  — bounded FIFO, per-tenant quotas, backpressure
  coalescer  — continuous same-bucket batching (lane budget / time window)
  stream     — per-client ResultStream handles (chunks + terminal status)
  metrics    — counters, latency percentiles, QPS, occupancy, cache stats

Entry point: ``python -m repro.launch.serve --smoke``.
"""

from repro.serve.admission import Backpressure, QuotaExceeded
from repro.serve.coalescer import Coalescer
from repro.serve.metrics import ServiceMetrics, format_snapshot
from repro.serve.service import EnumerationService, ServiceConfig
from repro.serve.stream import ResultChunk, ResultStatus, ResultStream, ServiceError

__all__ = [
    "Backpressure",
    "Coalescer",
    "EnumerationService",
    "QuotaExceeded",
    "ResultChunk",
    "ResultStatus",
    "ResultStream",
    "ServiceConfig",
    "ServiceError",
    "ServiceMetrics",
    "format_snapshot",
]
