"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs pure-jnp oracle.

Bitwise kernels ⇒ exact equality (no tolerance)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels import ref as kref

SHAPES_CM = [
    # (b, w, mp, n_rows, p_pad)
    (1, 1, 1, 2, 1),
    (4, 3, 2, 10, 5),
    (16, 130, 4, 64, 8),
    (8, 128, 8, 32, 64),
    (32, 257, 6, 100, 16),
    (64, 13, 3, 7, 4),
]


@pytest.mark.parametrize("b,w,mp,n_rows,p_pad", SHAPES_CM)
def test_candidate_mask(rng, b, w, mp, n_rows, p_pad):
    rows = np.concatenate(
        [
            rng.integers(0, 2**32, (n_rows, w), dtype=np.uint32),
            np.full((1, w), 0xFFFFFFFF, np.uint32),
        ],
        0,
    )
    dom = rng.integers(0, 2**32, (p_pad, w), dtype=np.uint32)
    pos = rng.integers(0, p_pad, b).astype(np.int32)
    row_idx = rng.integers(0, n_rows + 1, (b, mp)).astype(np.int32)
    used = rng.integers(0, 2**32, (b, w), dtype=np.uint32)
    got = ops.candidate_mask(
        jnp.asarray(rows), jnp.asarray(dom), jnp.asarray(pos),
        jnp.asarray(row_idx), jnp.asarray(used),
    )
    want = kref.candidate_mask_ref(
        jnp.asarray(rows), jnp.asarray(dom), jnp.asarray(pos),
        jnp.asarray(row_idx), jnp.asarray(used),
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("n,w", [(1, 1), (5, 1), (300, 10), (1000, 130), (257, 129)])
def test_adjacency_any_and_popcount(rng, n, w):
    rows = rng.integers(0, 2**32, (n, w), dtype=np.uint32)
    mask = rng.integers(0, 2**32, (w,), dtype=np.uint32)
    np.testing.assert_array_equal(
        np.asarray(ops.adjacency_any(jnp.asarray(rows), jnp.asarray(mask))),
        np.asarray(kref.adjacency_any_ref(jnp.asarray(rows), jnp.asarray(mask))),
    )
    np.testing.assert_array_equal(
        np.asarray(ops.popcount_rows(jnp.asarray(rows))),
        np.asarray(kref.popcount_rows_ref(jnp.asarray(rows))),
    )


@pytest.mark.parametrize(
    "n_planes,n_t,w,n_arcs",
    [(2, 1, 1, 1), (4, 10, 3, 6), (2, 300, 5, 16), (6, 257, 129, 9)],
)
def test_arc_any_sweep(rng, n_planes, n_t, w, n_arcs):
    """The whole-sweep scalar-prefetch kernel (one AC sweep's arcs in one
    pallas_call) against the lax.map oracle."""
    adj = rng.integers(0, 2**32, (n_planes, n_t, w), dtype=np.uint32)
    arc_row = rng.integers(0, n_planes, n_arcs).astype(np.int32)
    masks = rng.integers(0, 2**32, (n_arcs, w), dtype=np.uint32)
    got = ops.arc_any_sweep(jnp.asarray(adj), jnp.asarray(arc_row),
                            jnp.asarray(masks))
    want = kref.arc_any_sweep_ref(jnp.asarray(adj), jnp.asarray(arc_row),
                                  jnp.asarray(masks))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize(
    "n_planes,n_t,w,n_arcs,deg_cap",
    [(2, 4, 1, 2, 8), (4, 33, 2, 6, 8), (2, 300, 10, 9, 16), (6, 70, 3, 5, 32)],
)
def test_csr_arc_sweep(rng, n_planes, n_t, w, n_arcs, deg_cap):
    """The CSR-segment scalar-prefetch sweep (one AC sweep's arcs over
    sentinel-padded CSR segments) against the lax.map oracle — ragged
    degrees, empty rows, and full-deg_cap rows included."""
    degs = rng.integers(0, deg_cap + 1, (n_planes, n_t)).astype(np.int32)
    nnz = int(degs.sum())
    sentinel = np.int32(2**31 - 1)
    indices = np.full(nnz + deg_cap, sentinel, np.int32)
    seg_start = np.zeros((n_planes, n_t), np.int32)
    off = 0
    for p in range(n_planes):
        for t in range(n_t):
            seg_start[p, t] = off
            d = int(degs[p, t])
            indices[off:off + d] = rng.integers(0, n_t, d)
            off += d
    arc_row = rng.integers(0, n_planes, n_arcs).astype(np.int32)
    masks = rng.integers(0, 2**32, (n_arcs, w), dtype=np.uint32)
    got = ops.csr_arc_sweep(
        jnp.asarray(seg_start), jnp.asarray(degs), jnp.asarray(indices),
        jnp.asarray(arc_row), jnp.asarray(masks), deg_cap=deg_cap,
    )
    want = kref.csr_arc_sweep_ref(
        jnp.asarray(seg_start), jnp.asarray(degs), jnp.asarray(indices),
        jnp.asarray(arc_row), jnp.asarray(masks), deg_cap=deg_cap,
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_pack_bits_roundtrip(rng):
    n, w = 70, 3
    flags = rng.integers(0, 2, n).astype(np.int32)
    packed = kref.pack_bits_ref(jnp.asarray(flags), w)
    # unpack via popcount trick
    bits = np.asarray(packed)
    unpacked = [(int(bits[i // 32]) >> (i % 32)) & 1 for i in range(n)]
    assert unpacked == flags.tolist()


def test_flat_row_index():
    parent_pos = jnp.asarray([0, 2, -1], jnp.int32)
    parent_dir = jnp.asarray([0, 1, 0], jnp.int32)
    parent_elab = jnp.asarray([0, 1, 0], jnp.int32)
    mapping = jnp.asarray([7, -1, 3, -1], jnp.int32)
    idx = ops.flat_row_index(parent_pos, parent_dir, parent_elab, mapping,
                             n_t=10, n_rows=40)
    # parent 0: elab 0, dir 0, t=7 -> (0*2+0)*10+7 = 7
    # parent 1: elab 1, dir 1, t=3 -> (1*2+1)*10+3 = 33
    # parent 2: padded -> neutral row 40
    assert np.asarray(idx).tolist() == [7, 33, 40]


def test_engine_pallas_path_equivalence(rng):
    """The engine with use_pallas=True matches the jnp path end to end."""
    from repro.core import enumerate_subgraphs
    from tests.conftest import extract_connected_pattern, random_graph

    tgt = random_graph(rng, 20, 50, n_labels=2)
    pat = extract_connected_pattern(rng, tgt, 4)
    if pat.m == 0:
        pytest.skip("empty pattern")
    a = enumerate_subgraphs(pat, tgt, variant="ri", n_workers=2, expand_width=2)
    b = enumerate_subgraphs(pat, tgt, variant="ri", n_workers=2, expand_width=2,
                            use_pallas=True)
    assert (a.matches, a.states) == (b.matches, b.states)
