#!/usr/bin/env python3
"""Fail CI when a doc citation dangles.

Scans the source tree for ``<File>.md §<section>`` citations (the repo
convention for pointing code at docs/DESIGN.md, docs/EXPERIMENTS.md, …)
and verifies that

  1. the cited file exists (in ``docs/`` or the repo root), and
  2. it contains a heading for the cited section (a ``#``-line whose
     ``§<section>`` token matches exactly — ``§2`` does not resolve via a
     ``§2.2`` heading, and vice versa).

Usage: ``python tools/check_doc_citations.py`` (exit 1 on any dangling
citation, listing every offender).
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
SCAN_DIRS = ("src", "examples", "benchmarks", "tests", "tools")
DOC_DIRS = (ROOT / "docs", ROOT)

# "DESIGN.md §2.4", "EXPERIMENTS.md §Perf." (trailing dot = sentence end)
CITATION = re.compile(r"([A-Za-z0-9_\-]+\.md)\s*§([A-Za-z0-9.]+)")


def find_doc(name: str) -> Path | None:
    for d in DOC_DIRS:
        p = d / name
        if p.is_file():
            return p
    return None


def headings_sections(doc: Path) -> set:
    """All §-tokens appearing in markdown headings of ``doc``."""
    out = set()
    for line in doc.read_text(encoding="utf-8").splitlines():
        if line.lstrip().startswith("#"):
            out.update(m.group(1) for m in re.finditer(r"§([A-Za-z0-9.]+)", line))
    return out


def main() -> int:
    sections_cache: dict = {}
    errors = []
    for dirname in SCAN_DIRS:
        base = ROOT / dirname
        if not base.is_dir():
            continue
        for path in sorted(base.rglob("*.py")):
            text = path.read_text(encoding="utf-8", errors="replace")
            for lineno, line in enumerate(text.splitlines(), 1):
                for m in CITATION.finditer(line):
                    name, section = m.group(1), m.group(2).rstrip(".")
                    where = f"{path.relative_to(ROOT)}:{lineno}"
                    doc = find_doc(name)
                    if doc is None:
                        errors.append(f"{where}: cites missing file {name}")
                        continue
                    if doc not in sections_cache:
                        sections_cache[doc] = headings_sections(doc)
                    if section not in sections_cache[doc]:
                        errors.append(
                            f"{where}: {name} has no §{section} heading "
                            f"(has: {', '.join(sorted(sections_cache[doc])) or 'none'})"
                        )
    if errors:
        print(f"{len(errors)} dangling doc citation(s):")
        for e in errors:
            print(f"  {e}")
        return 1
    print("all doc citations resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
