"""Pallas TPU kernel: per-row popcount of packed bitmaps.

Used for domain-size vectors (SI tie-breaking), candidate counting, and the
engine's match statistics.  Grid over row tiles; each step reduces a
``(tr, w)`` uint32 block to ``(tr, 1)`` int32 counts with the VPU popcount.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.kernels.candidate_mask import pad_words

ROW_TILE = 256


def _kernel(bits_ref, out_ref):
    out_ref[...] = jnp.sum(
        lax.population_count(bits_ref[...]).astype(jnp.int32),
        axis=-1,
        keepdims=True,
    )


@functools.partial(jax.jit, static_argnames=("interpret", "row_tile"))
def popcount_rows(
    bits: jnp.ndarray,  # [n, w] uint32
    interpret: bool = True,
    row_tile: int = ROW_TILE,
) -> jnp.ndarray:
    n, w = bits.shape
    wp = pad_words(w)
    tr = row_tile
    n_pad = ((n + tr - 1) // tr) * tr
    bits_p = jnp.pad(bits, ((0, n_pad - n), (0, wp - w)))
    out = pl.pallas_call(
        _kernel,
        grid=(n_pad // tr,),
        in_specs=[pl.BlockSpec((tr, wp), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tr, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, 1), jnp.int32),
        interpret=interpret,
    )(bits_p)
    return out[:n, 0]
